//! Wire protocol + TCP server — the paper's §5 future-work I/O path
//! ("external image input, such as from a UART interface …, while
//! UART-based output can provide digit predictions to external systems").
//!
//! Two protocol versions share one port; the server sniffs the magic byte
//! (DESIGN.md §Wire protocol has the full field tables):
//!
//! **v1** (fixed-function, UART-friendly, still accepted unchanged):
//!
//! ```text
//!   request :  0xB1  len_lo len_hi  payload[len]      len = 98 (784 bits)
//!   response:  0xB2  digit  status  lat[4 LE, µs]     status 0 = OK
//!   error   :  0xBE  status 0x00    0x00000000
//! ```
//!
//! **v2** (versioned + batchable — the FINN-style streaming contract):
//!
//! ```text
//!   request :  0xC1  features top_k  id[8 LE]  n_images[2 LE]  n_bits[4 LE]
//!              [FEAT_MODEL: name_len + name_len × UTF-8 bytes]
//!              then n_images × ceil(n_bits/8) payload bytes
//!   response:  0xC2  status features top_k  id[8 LE]  n_items[2 LE]
//!              then per item: item_id[8 LE] digit[2 LE] lat[4 LE, µs]
//!                [FEAT_LOGITS: n[2 LE] + n × i32 LE]
//!                [FEAT_TOPK  : k + k × (class u16 LE, logit i32 LE)]
//! ```
//!
//! v2 request ids are **client-supplied** and echoed back; the i-th image
//! of a batch frame answers as `id + i`.  Widths are arbitrary
//! (1 ..= [`MAX_WIRE_BITS`] bits — the model still decides what it
//! accepts); protocol errors come back as a [`WireStatus`], never a hang.
//!
//! Payload bit order: bit *i* at byte `i/8` bit `i%8` (LSB-first — the
//! same order as the packed words).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::request::{InferOptions, InferResponse, Ticket};
use super::InferService;
use crate::bnn::packing::Packed;

pub const MAGIC_REQ: u8 = 0xB1;
pub const MAGIC_RESP: u8 = 0xB2;
pub const MAGIC_ERR: u8 = 0xBE;
pub const MAGIC_REQ_V2: u8 = 0xC1;
pub const MAGIC_RESP_V2: u8 = 0xC2;

/// v1 frames are fixed to the paper's 28×28 binarized images.
pub const IMAGE_BITS: usize = 784;
pub const PAYLOAD_BYTES: usize = IMAGE_BITS.div_ceil(8); // 98

/// v2 feature bits (request byte 1, echoed in responses).
pub const FEAT_LOGITS: u8 = 0x01;
pub const FEAT_TOPK: u8 = 0x02;
/// The request carries a model-name section (1 length byte + that many
/// UTF-8 bytes) between the fixed head and the payloads, naming the
/// registry model to serve it.  Echoed in responses but response frames
/// never carry a name section.  Absent ⇒ the server's default model, so
/// pre-registry clients are untouched.
pub const FEAT_MODEL: u8 = 0x04;
/// The request carries a deadline section (4 LE bytes: a *relative* budget
/// in µs — relative so it survives unsynchronized clocks) after the model
/// name (if any) and before the payloads.  The server arms
/// [`InferOptions::deadline`] at parse time; a request still queued when
/// the budget runs out answers [`WireStatus::DeadlineExceeded`].  Echoed in
/// responses but response frames never carry the section.
pub const FEAT_DEADLINE: u8 = 0x08;
pub const FEAT_MASK: u8 = FEAT_LOGITS | FEAT_TOPK | FEAT_MODEL | FEAT_DEADLINE;

/// Model names on the wire are 1..=64 bytes of UTF-8.
pub const MAX_MODEL_NAME: usize = 64;

/// Hard protocol limits — anything beyond them is a [`WireStatus::TooLarge`]
/// error, not an attempted allocation.
pub const MAX_WIRE_BITS: usize = 1 << 20;
pub const MAX_WIRE_BATCH: usize = 1024;
pub const MAX_WIRE_CLASSES: usize = 4096;

/// Shared error taxonomy, used as the v1 error code byte and the v2 status
/// byte (v1 kept its historical numeric values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum WireStatus {
    Ok = 0,
    BadMagic = 1,
    BadLength = 2,
    /// The backend refused the request (e.g. image width ≠ model width).
    Backend = 3,
    TooLarge = 4,
    BadFeature = 5,
    /// The engine's admission queue is at its cap — retry later.  Distinct
    /// from [`WireStatus::Backend`] so load generators and clients can tell
    /// overload (expected under stress, counted `rejected` in the ledger)
    /// from genuine backend refusal (width mismatch, dead worker).
    Overloaded = 6,
    /// The connection sat idle past the server's read timeout mid-frame.
    Timeout = 7,
    /// The request named a model the server's registry does not have.
    UnknownModel = 8,
    /// The request's [`FEAT_DEADLINE`] budget expired before a worker ran
    /// it — the engine shed it instead of serving a stale answer.
    DeadlineExceeded = 9,
    /// The worker executing the request panicked; the request is counted
    /// `rejected` and the (supervised) worker restarts.  Safe to retry.
    WorkerCrashed = 10,
    /// A status byte this build does not know (forward compatibility).
    Unknown = 255,
}

impl WireStatus {
    pub fn from_u8(b: u8) -> WireStatus {
        match b {
            0 => WireStatus::Ok,
            1 => WireStatus::BadMagic,
            2 => WireStatus::BadLength,
            3 => WireStatus::Backend,
            4 => WireStatus::TooLarge,
            5 => WireStatus::BadFeature,
            6 => WireStatus::Overloaded,
            7 => WireStatus::Timeout,
            8 => WireStatus::UnknownModel,
            9 => WireStatus::DeadlineExceeded,
            10 => WireStatus::WorkerCrashed,
            _ => WireStatus::Unknown,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireStatus::Ok => "ok",
            WireStatus::BadMagic => "bad-magic",
            WireStatus::BadLength => "bad-length",
            WireStatus::Backend => "backend-error",
            WireStatus::TooLarge => "too-large",
            WireStatus::BadFeature => "bad-feature",
            WireStatus::Overloaded => "overloaded",
            WireStatus::Timeout => "idle-timeout",
            WireStatus::UnknownModel => "unknown-model",
            WireStatus::DeadlineExceeded => "deadline-exceeded",
            WireStatus::WorkerCrashed => "worker-crashed",
            WireStatus::Unknown => "unknown-status",
        }
    }
}

/// Map an engine/registry submit/wait error onto the wire taxonomy:
/// admission refusals — the coordinator's "queue full (…)", the worker
/// pool's "shard N full (…)" and the registry's "quota exceeded (…)", all
/// counted `rejected` in the metrics ledger — become
/// [`WireStatus::Overloaded`]; a registry lookup miss ("unknown model …")
/// becomes [`WireStatus::UnknownModel`]; the typed [`super::request::Failure`]
/// substrings become [`WireStatus::DeadlineExceeded`] /
/// [`WireStatus::WorkerCrashed`]; everything else is a generic
/// [`WireStatus::Backend`].  The vendored `anyhow` subset has no
/// downcasting, but `{e:#}` renders the full context chain, so the match
/// is a substring test.
pub(crate) fn submit_error_status(e: &anyhow::Error) -> WireStatus {
    let chain = format!("{e:#}");
    if chain.contains("unknown model") {
        WireStatus::UnknownModel
    } else if chain.contains("deadline exceeded") {
        WireStatus::DeadlineExceeded
    } else if chain.contains("worker crashed") {
        WireStatus::WorkerCrashed
    } else if chain.contains("queue full")
        || chain.contains(" full (")
        || chain.contains("quota exceeded")
    {
        WireStatus::Overloaded
    } else {
        WireStatus::Backend
    }
}

/// A typed wire-layer failure: the status the peer should see, the frame id
/// when it was parsed far enough to know it, and a human-readable detail.
#[derive(Debug)]
pub struct WireError {
    pub status: WireStatus,
    pub id: Option<u64>,
    msg: String,
}

impl WireError {
    fn new(status: WireStatus, msg: impl Into<String>) -> Self {
        Self {
            status,
            id: None,
            msg: msg.into(),
        }
    }

    fn with_id(mut self, id: u64) -> Self {
        self.id = Some(id);
        self
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.status.name(), self.msg)
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// payload codec (shared by v1 and v2)

/// Bytes needed for an `n_bits` payload.
pub fn payload_bytes(n_bits: usize) -> usize {
    n_bits.div_ceil(8)
}

/// Serialize a packed image into LSB-first payload bytes.
///
/// The wire layout (bit *i* at byte `i/8`, bit `i%8`) is byte-identical to
/// the little-endian serialization of the packed u64 words (bit *i* at
/// word `i/64`, bit `i%64`), so this is a straight byte copy — no
/// per-bit work even at [`MAX_WIRE_BITS`]-sized images.
pub fn bits_to_payload(image: &Packed) -> Vec<u8> {
    let n = payload_bytes(image.n_bits);
    let mut payload = Vec::with_capacity(image.words.len() * 8);
    for w in &image.words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    payload.truncate(n);
    // mask padding bits of a partial final byte (defensive: a hand-built
    // Packed with dirty word padding must not leak onto the wire)
    if image.n_bits % 8 != 0 {
        if let Some(last) = payload.last_mut() {
            *last &= (1u8 << (image.n_bits % 8)) - 1;
        }
    }
    payload
}

pub(crate) fn unpack_payload(payload: &[u8], n_bits: usize) -> Packed {
    // inverse of `bits_to_payload`: the payload bytes are the words'
    // little-endian bytes (zero-padded tail), so assemble words directly
    let n_words = n_bits.div_ceil(64);
    let mut words = vec![0u64; n_words];
    for (i, chunk) in payload.chunks(8).enumerate() {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        words[i] = u64::from_le_bytes(b);
    }
    // ignore any payload bits at or beyond n_bits (same contract as the
    // old per-bit decoder)
    if n_bits % 64 != 0 {
        words[n_words - 1] &= (1u64 << (n_bits % 64)) - 1;
    }
    Packed { words, n_bits }
}

/// Decode an exactly-sized payload into a packed image, with explicit
/// truncated/oversized diagnostics.
pub fn payload_to_packed(payload: &[u8], n_bits: usize) -> Result<Packed> {
    anyhow::ensure!(n_bits >= 1, "payload width must be ≥ 1 bit");
    let want = payload_bytes(n_bits);
    if payload.len() < want {
        bail!(
            "truncated payload: {} of {want} bytes for {n_bits} bits",
            payload.len()
        );
    }
    if payload.len() > want {
        bail!(
            "oversized payload: {} bytes where {n_bits} bits need {want}",
            payload.len()
        );
    }
    Ok(unpack_payload(payload, n_bits))
}

// ---------------------------------------------------------------------------
// v1 frames

/// Encode a packed image as a v1 request frame.  v1 is fixed-width: any
/// other size is an error (v2 carries arbitrary widths).
pub fn encode_request(image: &Packed) -> Result<Vec<u8>> {
    anyhow::ensure!(
        image.n_bits == IMAGE_BITS,
        "v1 frames are fixed at {IMAGE_BITS} bits, got {} — use the v2 protocol \
         (encode_request_v2) for other widths",
        image.n_bits
    );
    let payload = bits_to_payload(image);
    let mut frame = Vec::with_capacity(3 + PAYLOAD_BYTES);
    frame.push(MAGIC_REQ);
    frame.extend_from_slice(&(PAYLOAD_BYTES as u16).to_le_bytes());
    frame.extend_from_slice(&payload);
    Ok(frame)
}

/// Decode a v1 request payload into a packed image.
pub fn decode_payload(payload: &[u8]) -> Result<Packed> {
    payload_to_packed(payload, IMAGE_BITS)
}

/// A parsed v1 response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireResponse {
    pub digit: u8,
    pub status: u8,
    pub latency_us: u32,
}

pub fn encode_response(digit: u8, latency_us: u32) -> [u8; 7] {
    let l = latency_us.to_le_bytes();
    [MAGIC_RESP, digit, 0, l[0], l[1], l[2], l[3]]
}

pub fn encode_error(status: WireStatus) -> [u8; 7] {
    [MAGIC_ERR, status as u8, 0, 0, 0, 0, 0]
}

pub fn decode_response(frame: &[u8; 7]) -> Result<WireResponse> {
    match frame[0] {
        MAGIC_RESP => Ok(WireResponse {
            digit: frame[1],
            status: frame[2],
            latency_us: u32::from_le_bytes([frame[3], frame[4], frame[5], frame[6]]),
        }),
        MAGIC_ERR => bail!("server error: {}", WireStatus::from_u8(frame[1]).name()),
        m => bail!("bad response magic {m:#x}"),
    }
}

// ---------------------------------------------------------------------------
// v2 frames

/// A parsed v2 request frame: client-supplied id, per-request options, and
/// one or more equal-width images.
#[derive(Clone, Debug)]
pub struct WireRequestV2 {
    pub id: u64,
    pub opts: InferOptions,
    /// Registry model to serve this frame ([`FEAT_MODEL`] section);
    /// `None` ⇒ the server's default model.
    pub model: Option<String>,
    pub images: Vec<Packed>,
}

/// One classified image inside a v2 response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireItem {
    /// Echoed id: the frame id plus the image's index within its batch.
    pub id: u64,
    /// u16 like the top-k class carrier: a >255-class model's argmax rides
    /// the wire unwrapped (2 LE bytes per item since the digit widening).
    pub digit: u16,
    pub latency_us: u32,
    /// Present iff the request set [`FEAT_LOGITS`].
    pub logits: Vec<i32>,
    /// Present iff the request set [`FEAT_TOPK`]; best first.  Class ids
    /// are u16 on the wire ([`MAX_WIRE_CLASSES`] fits).
    pub top_k: Vec<(u16, i32)>,
}

/// A parsed v2 response frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireResponseV2 {
    pub id: u64,
    pub status: WireStatus,
    pub features: u8,
    pub items: Vec<WireItem>,
}

/// The v2 `(features, top_k)` header bytes for a set of options.  Typed
/// error (never a silent wrap) when `top_k` exceeds the one-byte carrier.
/// A set [`InferOptions::deadline`] raises [`FEAT_DEADLINE`] (the budget
/// itself rides in the request's deadline section, not the header).
pub fn encode_features(opts: &InferOptions) -> Result<(u8, u8)> {
    let mut features = 0u8;
    if opts.include_logits {
        features |= FEAT_LOGITS;
    }
    if opts.deadline.is_some() {
        features |= FEAT_DEADLINE;
    }
    let k = match opts.top_k {
        Some(k) => {
            anyhow::ensure!((1..=255).contains(&k), "top_k must be in 1..=255, got {k}");
            features |= FEAT_TOPK;
            k as u8
        }
        None => 0,
    };
    Ok((features, k))
}

/// Header-only options: [`FEAT_DEADLINE`]'s budget lives in its own
/// section, so `deadline` stays `None` here and the readers arm it once
/// the section is parsed.
fn decode_features(features: u8, top_k: u8) -> InferOptions {
    InferOptions {
        include_logits: features & FEAT_LOGITS != 0,
        top_k: (features & FEAT_TOPK != 0).then_some(top_k as usize),
        deadline: None,
    }
}

/// Arm a parsed [`FEAT_DEADLINE`] budget (µs, relative) against `now`:
/// the absolute instant workers compare against on dequeue.
pub(crate) fn arm_deadline(budget_us: u32, now: std::time::Instant) -> std::time::Instant {
    now + std::time::Duration::from_micros(budget_us as u64)
}

/// The µs budget a request's deadline leaves at `now`, saturating both
/// ways: an already-expired deadline encodes as 0 (the server sheds it on
/// arrival — still a typed answer, never a hang) and a distant one clamps
/// to the u32 carrier.
pub(crate) fn budget_us(deadline: std::time::Instant, now: std::time::Instant) -> u32 {
    deadline
        .saturating_duration_since(now)
        .as_micros()
        .min(u32::MAX as u128) as u32
}

/// Encode a v2 request frame: `id` is echoed back, image `i` answers as
/// `id + i`.  All images must share one width in `1..=MAX_WIRE_BITS`.
pub fn encode_request_v2(images: &[Packed], id: u64, opts: InferOptions) -> Result<Vec<u8>> {
    encode_request_v2_for(images, id, opts, None)
}

/// [`encode_request_v2`] addressed to a named registry model: sets
/// [`FEAT_MODEL`] and inserts the name section between the head and the
/// payloads.  `None` encodes the plain frame (default model).
pub fn encode_request_v2_for(
    images: &[Packed],
    id: u64,
    opts: InferOptions,
    model: Option<&str>,
) -> Result<Vec<u8>> {
    anyhow::ensure!(!images.is_empty(), "a v2 frame needs ≥ 1 image");
    if let Some(name) = model {
        anyhow::ensure!(
            (1..=MAX_MODEL_NAME).contains(&name.len()),
            "model name must be 1..={MAX_MODEL_NAME} bytes, got {}",
            name.len()
        );
    }
    anyhow::ensure!(
        images.len() <= MAX_WIRE_BATCH,
        "{} images exceed the per-frame batch limit {MAX_WIRE_BATCH}",
        images.len()
    );
    let n_bits = images[0].n_bits;
    anyhow::ensure!(
        (1..=MAX_WIRE_BITS).contains(&n_bits),
        "image width {n_bits} outside 1..={MAX_WIRE_BITS}"
    );
    for (i, img) in images.iter().enumerate() {
        anyhow::ensure!(
            img.n_bits == n_bits,
            "a v2 frame carries one width: image 0 has {n_bits} bits, image {i} has {}",
            img.n_bits
        );
    }
    let (mut features, top_k) = encode_features(&opts)?;
    if model.is_some() {
        features |= FEAT_MODEL;
    }
    let mut frame = Vec::with_capacity(17 + images.len() * payload_bytes(n_bits));
    frame.push(MAGIC_REQ_V2);
    frame.push(features);
    frame.push(top_k);
    frame.extend_from_slice(&id.to_le_bytes());
    frame.extend_from_slice(&(images.len() as u16).to_le_bytes());
    frame.extend_from_slice(&(n_bits as u32).to_le_bytes());
    if let Some(name) = model {
        frame.push(name.len() as u8);
        frame.extend_from_slice(name.as_bytes());
    }
    if let Some(deadline) = opts.deadline {
        let budget = budget_us(deadline, std::time::Instant::now());
        frame.extend_from_slice(&budget.to_le_bytes());
    }
    for img in images {
        frame.extend_from_slice(&bits_to_payload(img));
    }
    Ok(frame)
}

/// Read-timeout errors surface as `TimedOut` (or `WouldBlock` on platforms
/// where `SO_RCVTIMEO` reports it that way).
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
    )
}

fn truncated(what: &str) -> impl Fn(std::io::Error) -> WireError + '_ {
    move |e| {
        if is_timeout(&e) {
            WireError::new(WireStatus::Timeout, format!("idle while reading {what}: {e}"))
        } else {
            WireError::new(WireStatus::BadLength, format!("truncated {what}: {e}"))
        }
    }
}

/// The fixed 16-byte v2 request head (after the magic byte), validated.
#[derive(Clone, Copy, Debug)]
pub(crate) struct V2Header {
    pub features: u8,
    pub top_k: u8,
    pub id: u64,
    pub n_images: usize,
    pub n_bits: usize,
}

impl V2Header {
    pub(crate) fn opts(&self) -> InferOptions {
        decode_features(self.features, self.top_k)
    }
}

/// Validate a raw 16-byte v2 request head.  Shared by the blocking reader
/// ([`read_request_v2_body`]) and the async server's incremental parser so
/// the two paths cannot drift on limits or statuses.
pub(crate) fn parse_v2_header(head: &[u8; 16]) -> Result<V2Header, WireError> {
    let features = head[0];
    let top_k = head[1];
    let id = u64::from_le_bytes(head[2..10].try_into().unwrap());
    let n_images = u16::from_le_bytes([head[10], head[11]]) as usize;
    let n_bits = u32::from_le_bytes(head[12..16].try_into().unwrap()) as usize;
    if features & !FEAT_MASK != 0 {
        return Err(
            WireError::new(WireStatus::BadFeature, format!("unknown feature bits {features:#04x}"))
                .with_id(id),
        );
    }
    if features & FEAT_TOPK != 0 && top_k == 0 {
        return Err(WireError::new(WireStatus::BadFeature, "top-k requested with k = 0").with_id(id));
    }
    if n_images == 0 {
        return Err(WireError::new(WireStatus::BadLength, "v2 frame with 0 images").with_id(id));
    }
    if n_images > MAX_WIRE_BATCH {
        return Err(WireError::new(
            WireStatus::TooLarge,
            format!("{n_images} images exceed the per-frame batch limit {MAX_WIRE_BATCH}"),
        )
        .with_id(id));
    }
    if n_bits == 0 {
        return Err(WireError::new(WireStatus::BadLength, "v2 frame with 0-bit images").with_id(id));
    }
    if n_bits > MAX_WIRE_BITS {
        return Err(WireError::new(
            WireStatus::TooLarge,
            format!("image width {n_bits} exceeds the limit {MAX_WIRE_BITS}"),
        )
        .with_id(id));
    }
    Ok(V2Header {
        features,
        top_k,
        id,
        n_images,
        n_bits,
    })
}

/// Validate a [`FEAT_MODEL`] section length byte.  Shared by the blocking
/// reader and the async server's incremental parser (which needs the check
/// before the frame's total size is even known).
pub(crate) fn check_model_name_len(len: usize) -> Result<(), WireError> {
    if len == 0 {
        return Err(WireError::new(
            WireStatus::BadLength,
            "FEAT_MODEL set with an empty model name",
        ));
    }
    if len > MAX_MODEL_NAME {
        return Err(WireError::new(
            WireStatus::TooLarge,
            format!("model name of {len} bytes exceeds the limit {MAX_MODEL_NAME}"),
        ));
    }
    Ok(())
}

/// Decode a [`FEAT_MODEL`] name section body (length already validated).
pub(crate) fn parse_model_name(bytes: &[u8]) -> Result<String, WireError> {
    std::str::from_utf8(bytes)
        .map(str::to_string)
        .map_err(|_| WireError::new(WireStatus::BadLength, "model name is not valid UTF-8"))
}

/// Read and validate a v2 request body from `r` — the magic byte has
/// already been consumed by the dispatcher.
pub fn read_request_v2_body(r: &mut impl Read) -> Result<WireRequestV2, WireError> {
    let mut head = [0u8; 16];
    r.read_exact(&mut head).map_err(truncated("v2 header"))?;
    let h = parse_v2_header(&head)?;
    let model = if h.features & FEAT_MODEL != 0 {
        let mut len_b = [0u8; 1];
        r.read_exact(&mut len_b).map_err(truncated("model name length"))?;
        check_model_name_len(len_b[0] as usize).map_err(|e| e.with_id(h.id))?;
        let mut name = vec![0u8; len_b[0] as usize];
        r.read_exact(&mut name).map_err(truncated("model name"))?;
        Some(parse_model_name(&name).map_err(|e| e.with_id(h.id))?)
    } else {
        None
    };
    let mut opts = h.opts();
    if h.features & FEAT_DEADLINE != 0 {
        let mut budget = [0u8; 4];
        r.read_exact(&mut budget).map_err(truncated("deadline section"))?;
        opts.deadline = Some(arm_deadline(
            u32::from_le_bytes(budget),
            std::time::Instant::now(),
        ));
    }
    let pb = payload_bytes(h.n_bits);
    let mut payload = vec![0u8; pb];
    let mut images = Vec::with_capacity(h.n_images);
    for i in 0..h.n_images {
        r.read_exact(&mut payload)
            .map_err(|e| {
                let status = if is_timeout(&e) {
                    WireStatus::Timeout
                } else {
                    WireStatus::BadLength
                };
                WireError::new(status, format!("truncated payload for image {i}: {e}")).with_id(h.id)
            })?;
        images.push(unpack_payload(&payload, h.n_bits));
    }
    Ok(WireRequestV2 {
        id: h.id,
        opts,
        model,
        images,
    })
}

/// Encode a v2 response frame (`status != Ok` ⇒ `items` is empty).
/// The write side enforces the same limits the read side checks, so the
/// encoder can never emit a frame its own decoder rejects — or silently
/// truncate a count field and desync the stream.
pub fn encode_response_v2(
    id: u64,
    status: WireStatus,
    features: u8,
    top_k: u8,
    items: &[WireItem],
) -> Result<Vec<u8>> {
    anyhow::ensure!(
        items.len() <= MAX_WIRE_BATCH,
        "{} response items exceed the batch limit {MAX_WIRE_BATCH}",
        items.len()
    );
    for it in items {
        if features & FEAT_LOGITS != 0 {
            anyhow::ensure!(
                it.logits.len() <= MAX_WIRE_CLASSES,
                "{} logits exceed the class limit {MAX_WIRE_CLASSES}",
                it.logits.len()
            );
        }
        if features & FEAT_TOPK != 0 {
            anyhow::ensure!(
                it.top_k.len() <= 255,
                "top-k section of {} entries exceeds 255",
                it.top_k.len()
            );
        }
    }
    let mut f = Vec::with_capacity(14 + items.len() * 14);
    f.push(MAGIC_RESP_V2);
    f.push(status as u8);
    f.push(features);
    f.push(top_k);
    f.extend_from_slice(&id.to_le_bytes());
    f.extend_from_slice(&(items.len() as u16).to_le_bytes());
    for it in items {
        f.extend_from_slice(&it.id.to_le_bytes());
        f.extend_from_slice(&it.digit.to_le_bytes());
        f.extend_from_slice(&it.latency_us.to_le_bytes());
        if features & FEAT_LOGITS != 0 {
            f.extend_from_slice(&(it.logits.len() as u16).to_le_bytes());
            for &l in &it.logits {
                f.extend_from_slice(&l.to_le_bytes());
            }
        }
        if features & FEAT_TOPK != 0 {
            f.push(it.top_k.len() as u8);
            for &(class, logit) in &it.top_k {
                f.extend_from_slice(&class.to_le_bytes());
                f.extend_from_slice(&logit.to_le_bytes());
            }
        }
    }
    Ok(f)
}

/// A v2 error frame: non-Ok status, zero items.
pub fn encode_error_v2(id: u64, status: WireStatus) -> Vec<u8> {
    encode_response_v2(id, status, 0, 0, &[]).expect("an empty v2 frame always encodes")
}

/// Read one complete v2 response frame (including the magic byte) from `r`.
pub fn read_response_v2(r: &mut impl Read) -> Result<WireResponseV2, WireError> {
    let mut head = [0u8; 14];
    r.read_exact(&mut head).map_err(truncated("v2 response header"))?;
    if head[0] != MAGIC_RESP_V2 {
        return Err(WireError::new(
            WireStatus::BadMagic,
            format!("bad v2 response magic {:#04x}", head[0]),
        ));
    }
    let status = WireStatus::from_u8(head[1]);
    let features = head[2];
    let id = u64::from_le_bytes(head[4..12].try_into().unwrap());
    let n_items = u16::from_le_bytes([head[12], head[13]]) as usize;
    if features & !FEAT_MASK != 0 {
        return Err(WireError::new(
            WireStatus::BadFeature,
            format!("unknown response feature bits {features:#04x}"),
        )
        .with_id(id));
    }
    if n_items > MAX_WIRE_BATCH {
        return Err(WireError::new(
            WireStatus::TooLarge,
            format!("{n_items} response items exceed the batch limit {MAX_WIRE_BATCH}"),
        )
        .with_id(id));
    }
    let mut items = Vec::with_capacity(n_items);
    for i in 0..n_items {
        let mut fixed = [0u8; 14];
        r.read_exact(&mut fixed)
            .map_err(|e| {
                WireError::new(WireStatus::BadLength, format!("truncated response item {i}: {e}"))
                    .with_id(id)
            })?;
        let item_id = u64::from_le_bytes(fixed[0..8].try_into().unwrap());
        let digit = u16::from_le_bytes([fixed[8], fixed[9]]);
        let latency_us = u32::from_le_bytes(fixed[10..14].try_into().unwrap());
        let logits = if features & FEAT_LOGITS != 0 {
            let mut nb = [0u8; 2];
            r.read_exact(&mut nb).map_err(truncated("logits length"))?;
            let n = u16::from_le_bytes(nb) as usize;
            if n > MAX_WIRE_CLASSES {
                return Err(WireError::new(
                    WireStatus::TooLarge,
                    format!("{n} logits exceed the class limit {MAX_WIRE_CLASSES}"),
                )
                .with_id(id));
            }
            let mut buf = vec![0u8; n * 4];
            r.read_exact(&mut buf).map_err(truncated("logits section"))?;
            buf.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        } else {
            Vec::new()
        };
        let top_k = if features & FEAT_TOPK != 0 {
            let mut kb = [0u8; 1];
            r.read_exact(&mut kb).map_err(truncated("top-k length"))?;
            let mut buf = vec![0u8; kb[0] as usize * 6];
            r.read_exact(&mut buf).map_err(truncated("top-k section"))?;
            buf.chunks_exact(6)
                .map(|c| {
                    (
                        u16::from_le_bytes([c[0], c[1]]),
                        i32::from_le_bytes(c[2..6].try_into().unwrap()),
                    )
                })
                .collect()
        } else {
            Vec::new()
        };
        items.push(WireItem {
            id: item_id,
            digit,
            latency_us,
            logits,
            top_k,
        });
    }
    Ok(WireResponseV2 {
        id,
        status,
        features,
        items,
    })
}

// ---------------------------------------------------------------------------
// server

/// Where a wire server sends the requests it parses: one [`InferService`]
/// (the pre-registry shape — [`FEAT_MODEL`] names are accepted and
/// ignored, there is nothing to route between) or a
/// [`super::ModelRegistry`] that routes by the frame's model name
/// (absent ⇒ the registry's default model, unknown ⇒
/// [`WireStatus::UnknownModel`]).  Shared by the blocking and async
/// servers so the two front ends cannot drift on routing semantics.
#[derive(Clone)]
pub enum Dispatch {
    Single(Arc<dyn InferService>),
    Registry(Arc<super::router::ModelRegistry>),
}

impl Dispatch {
    pub(crate) fn submit(
        &self,
        model: Option<&str>,
        image: Packed,
        opts: InferOptions,
    ) -> Result<Ticket> {
        match self {
            Dispatch::Single(s) => s.submit_with(image, opts),
            Dispatch::Registry(r) => r.submit_to(model, image, opts),
        }
    }
}

/// Connection policy shared by the blocking and async servers.
#[derive(Clone, Copy, Debug)]
pub struct WireServerConfig {
    /// Concurrent-connection cap: connection `max_conns + 1` is answered
    /// with a best-effort [`WireStatus::Overloaded`] error frame and closed
    /// instead of being admitted (and, in the blocking server, instead of
    /// spawning an unbounded detached thread).
    pub max_conns: usize,
    /// Per-connection idle *read* timeout: a connection that goes silent
    /// mid-frame for this long is answered with [`WireStatus::Timeout`] and
    /// dropped, so a stalled client can't pin a handler thread (or an
    /// event-loop slot) forever.  Idleness *between* frames is fine on the
    /// async server; the blocking server applies the timeout to the magic
    /// byte too (one blocked thread per idle connection is the resource
    /// the timeout exists to reclaim).
    pub idle_timeout: std::time::Duration,
}

impl Default for WireServerConfig {
    fn default() -> Self {
        WireServerConfig {
            max_conns: 4096,
            idle_timeout: std::time::Duration::from_secs(60),
        }
    }
}

/// A running TCP server bound to a serving engine (thread-per-connection;
/// see [`super::AsyncWireServer`] for the readiness-polled high-fanout one).
pub struct WireServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Images served OK (a v2 batch frame counts once per image).
    pub served: Arc<AtomicU64>,
    /// Connection gauges (`conn_accepted == conn_closed + conn_open`); the
    /// request-ledger counters stay on the engine's own metrics.
    metrics: Arc<super::metrics::Metrics>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

/// Balances the connection gauges on every handler exit path (including
/// panics): `conn_open -= 1`, `conn_closed += 1` on drop.
struct OpenConnGuard(Arc<super::metrics::Metrics>);

impl Drop for OpenConnGuard {
    fn drop(&mut self) {
        self.0.conn_open.fetch_sub(1, Ordering::SeqCst);
        self.0.conn_closed.fetch_add(1, Ordering::SeqCst);
    }
}

impl WireServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve requests through any
    /// [`InferService`] — usually an [`super::Engine`] — with the default
    /// connection policy.
    pub fn start<S: InferService + 'static>(addr: &str, service: Arc<S>) -> Result<WireServer> {
        Self::start_with(addr, service, WireServerConfig::default())
    }

    /// [`Self::start`] with an explicit connection cap / idle timeout.
    pub fn start_with<S: InferService + 'static>(
        addr: &str,
        service: Arc<S>,
        cfg: WireServerConfig,
    ) -> Result<WireServer> {
        Self::start_dispatch(addr, Dispatch::Single(service), cfg)
    }

    /// Serve a [`super::ModelRegistry`]: v2 frames route by their
    /// [`FEAT_MODEL`] name, nameless frames (and all of v1) go to the
    /// registry's default model.
    pub fn start_registry(
        addr: &str,
        registry: Arc<super::router::ModelRegistry>,
    ) -> Result<WireServer> {
        Self::start_dispatch(addr, Dispatch::Registry(registry), WireServerConfig::default())
    }

    /// [`Self::start_registry`] with an explicit connection policy.
    pub fn start_registry_with(
        addr: &str,
        registry: Arc<super::router::ModelRegistry>,
        cfg: WireServerConfig,
    ) -> Result<WireServer> {
        Self::start_dispatch(addr, Dispatch::Registry(registry), cfg)
    }

    fn start_dispatch(addr: &str, dispatch: Dispatch, cfg: WireServerConfig) -> Result<WireServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(super::metrics::Metrics::default());
        let t_stop = stop.clone();
        let t_served = served.clone();
        let t_metrics = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("bnn-wire-accept".into())
            .spawn(move || {
                while !t_stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            t_metrics.conn_accepted.fetch_add(1, Ordering::SeqCst);
                            if t_metrics.conn_open.load(Ordering::SeqCst) >= cfg.max_conns {
                                // over the cap: refuse in the lowest common
                                // form and close — never spawn the thread
                                let _ = stream.write_all(&encode_error(WireStatus::Overloaded));
                                t_metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                            t_metrics.conn_open.fetch_add(1, Ordering::SeqCst);
                            let guard = OpenConnGuard(t_metrics.clone());
                            let dispatch = dispatch.clone();
                            let served = t_served.clone();
                            std::thread::spawn(move || {
                                let _guard = guard;
                                let _ = handle_conn(stream, dispatch, served, cfg.idle_timeout);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(WireServer {
            addr: local,
            stop,
            served,
            metrics,
            accept_thread: Some(handle),
        })
    }

    /// Connection gauges (`conn_accepted`/`conn_open`/`conn_closed`).
    pub fn metrics(&self) -> &Arc<super::metrics::Metrics> {
        &self.metrics
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    mut stream: TcpStream,
    dispatch: Dispatch,
    served: Arc<AtomicU64>,
    idle_timeout: std::time::Duration,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // SO_RCVTIMEO gives every blocking read the idle bound; a zero duration
    // would mean "no timeout", so clamp defensively.
    stream
        .set_read_timeout(Some(idle_timeout.max(std::time::Duration::from_millis(1))))
        .ok();
    loop {
        let mut magic = [0u8; 1];
        match stream.read_exact(&mut magic) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) if is_timeout(&e) => {
                // silent past the idle bound: tell the peer why and hang up
                let _ = stream.write_all(&encode_error(WireStatus::Timeout));
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        match magic[0] {
            MAGIC_REQ => handle_v1(&mut stream, &dispatch, &served)?,
            MAGIC_REQ_V2 => handle_v2(&mut stream, &dispatch, &served)?,
            m => {
                // version unknown, so answer in the lowest common form and
                // drop the connection (framing can't be trusted any more)
                let _ = stream.write_all(&encode_error(WireStatus::BadMagic));
                bail!("bad request magic {m:#x}");
            }
        }
    }
}

fn handle_v1(
    stream: &mut TcpStream,
    dispatch: &Dispatch,
    served: &Arc<AtomicU64>,
) -> Result<()> {
    // mid-frame reads: a stall here is a slow-loris, not idleness between
    // requests — answer with the typed timeout and drop the connection
    let read_or_timeout = |stream: &mut TcpStream, buf: &mut [u8]| -> Result<()> {
        match stream.read_exact(buf) {
            Ok(()) => Ok(()),
            Err(e) if is_timeout(&e) => {
                let _ = stream.write_all(&encode_error(WireStatus::Timeout));
                Err(e.into())
            }
            Err(e) => Err(e.into()),
        }
    };
    let mut len_b = [0u8; 2];
    read_or_timeout(stream, &mut len_b)?;
    let len = u16::from_le_bytes(len_b) as usize;
    if len != PAYLOAD_BYTES {
        stream.write_all(&encode_error(WireStatus::BadLength))?;
        bail!("bad v1 payload length {len} (expected {PAYLOAD_BYTES})");
    }
    let mut payload = vec![0u8; len];
    read_or_timeout(stream, &mut payload)?;
    // A v1 response carries only the digit, so serve the request through
    // the top-1-only path (`digits_only`): the worker computes the digit
    // from its flat logits arena and the per-request `n_classes` logits
    // copy never happens — the v1 serve loop is allocation-free end to
    // end (`BnnModel::predict_into` semantics through the engine).
    match decode_payload(&payload)
        .and_then(|img| dispatch.submit(None, img, InferOptions::digits_only()))
        .and_then(Ticket::wait)
    {
        // the v1 digit field is one byte: a >255-class argmax gets a typed
        // refusal, never a wrapped digit (v2 carries the u16)
        Ok(resp) if resp.digit > u8::MAX as u16 => {
            stream.write_all(&encode_error(WireStatus::TooLarge))?;
        }
        Ok(resp) => {
            let us = (resp.latency_ns / 1000).min(u32::MAX as u64) as u32;
            stream.write_all(&encode_response(resp.digit as u8, us))?;
            served.fetch_add(1, Ordering::Relaxed);
        }
        // typed refusal: queue-cap rejections surface as Overloaded so an
        // open-loop client can count shed load separately from failures
        Err(e) => stream.write_all(&encode_error(submit_error_status(&e)))?,
    }
    Ok(())
}

fn handle_v2(
    stream: &mut TcpStream,
    dispatch: &Dispatch,
    served: &Arc<AtomicU64>,
) -> Result<()> {
    let req = match read_request_v2_body(stream) {
        Ok(r) => r,
        Err(e) => {
            // protocol-level failure: answer with the typed status, then
            // drop the connection (stream position is undefined)
            let _ = stream.write_all(&encode_error_v2(e.id.unwrap_or(0), e.status));
            return Err(e.into());
        }
    };
    let (mut features, top_k) =
        encode_features(&req.opts).expect("wire-decoded options always re-encode");
    if req.model.is_some() {
        features |= FEAT_MODEL;
    }
    let opts = req.opts;
    let model = req.model.as_deref();
    // Submit the whole frame before waiting on anything (one burst for
    // the dynamic batcher), with no short-circuit at either stage: every
    // submit is attempted and every created ticket is waited, even when
    // some fail.  A failed frame is the engine's `rejected` count —
    // dropping live tickets early would miscount them as client cancels.
    let submitted: Vec<Result<Ticket>> = req
        .images
        .into_iter()
        .map(|img| dispatch.submit(model, img, opts))
        .collect();
    let waited: Vec<Result<InferResponse>> = submitted
        .into_iter()
        .map(|t| t.and_then(Ticket::wait))
        .collect();
    let responses: Result<Vec<InferResponse>> = waited.into_iter().collect();
    match responses {
        Ok(rs) => {
            let items: Vec<WireItem> = rs
                .into_iter()
                .enumerate()
                .map(|(i, r)| WireItem {
                    id: req.id.wrapping_add(i as u64),
                    digit: r.digit,
                    latency_us: (r.latency_ns / 1000).min(u32::MAX as u64) as u32,
                    logits: r.logits,
                    top_k: r.top_k,
                })
                .collect();
            match encode_response_v2(req.id, WireStatus::Ok, features, top_k, &items) {
                Ok(frame) => {
                    stream.write_all(&frame)?;
                    served.fetch_add(items.len() as u64, Ordering::Relaxed);
                }
                // e.g. a model with more classes than the wire carries
                Err(_) => stream.write_all(&encode_error_v2(req.id, WireStatus::TooLarge))?,
            }
        }
        // backend refusal (e.g. width mismatch) or queue-cap overload fails
        // the whole frame but keeps the connection: the frame boundary is
        // intact.  The first failure decides the typed status.
        Err(e) => stream.write_all(&encode_error_v2(req.id, submit_error_status(&e)))?,
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// client

/// Bounded exponential backoff with deterministic jitter for client-side
/// retries on [`WireStatus::Overloaded`] / [`WireStatus::Timeout`] — the
/// two statuses that mean "the server is fine, just busy / you were idle",
/// where resubmitting is safe and useful.  [`Self::delay_for`] is a pure
/// function of `(seed, attempt)`, so tests pin the exact schedule.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total tries including the first (so `1` disables retrying).
    pub max_attempts: u32,
    /// Backoff before retry `n` is `base << n`, capped at [`Self::cap`],
    /// plus jitter in `[0, backoff/2]`.
    pub base: std::time::Duration,
    pub cap: std::time::Duration,
    /// Jitter seed — splitmix-hashed with the attempt index, so two
    /// clients with different seeds desynchronize their retry storms.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: std::time::Duration::from_millis(1),
            cap: std::time::Duration::from_millis(100),
            seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry number `attempt` (0-based: the delay between
    /// the first failure and the second try is `delay_for(0)`).
    pub fn delay_for(&self, attempt: u32) -> std::time::Duration {
        let base_ns = self.base.as_nanos().min(u64::MAX as u128);
        let backoff_ns = (base_ns << attempt.min(64))
            .min(self.cap.as_nanos())
            .min(u64::MAX as u128) as u64;
        let jitter_ns = crate::util::prng::SplitMix64::new(self.seed ^ attempt as u64)
            .next_u64()
            % (backoff_ns / 2 + 1);
        std::time::Duration::from_nanos(backoff_ns.saturating_add(jitter_ns))
    }
}

/// Blocking client for tests/tools.  Speaks v1 ([`Self::classify`]) and v2
/// ([`Self::classify_v2`], [`Self::classify_batch`],
/// [`Self::classify_pipelined`]); v2 request ids are drawn from a
/// per-connection counter and verified against the echoes.
///
/// With [`Self::with_retry`], `Overloaded`/`Timeout` answers on the
/// round-trip paths reconnect and resubmit under the policy's backoff
/// schedule instead of surfacing immediately ([`Self::retries_attempted`]
/// counts the resubmits).
pub struct WireClient {
    stream: TcpStream,
    next_id: u64,
    addr: std::net::SocketAddr,
    retry: Option<RetryPolicy>,
    retries_attempted: u64,
}

impl WireClient {
    /// Max unanswered frames [`Self::classify_pipelined`] keeps in flight
    /// (64 single-image requests ≈ a few KB — far under any socket
    /// buffer, while still hiding the per-frame round trip).
    pub const PIPELINE_WINDOW: usize = 64;

    pub fn connect(addr: std::net::SocketAddr) -> Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(WireClient {
            stream,
            next_id: 1,
            addr,
            retry: None,
            retries_attempted: 0,
        })
    }

    /// Retry `Overloaded`/`Timeout` answers under `policy` instead of
    /// surfacing them on the first hit.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    /// Resubmits performed by the retry policy so far (0 without one).
    pub fn retries_attempted(&self) -> u64 {
        self.retries_attempted
    }

    /// Should `status` on try number `attempt` (0-based) be retried?
    fn wants_retry(&self, status: WireStatus, attempt: u32) -> bool {
        matches!(status, WireStatus::Overloaded | WireStatus::Timeout)
            && self
                .retry
                .is_some_and(|p| attempt.saturating_add(1) < p.max_attempts)
    }

    /// Book one retry: sleep the policy's backoff for `attempt`, then
    /// reconnect (an `Overloaded`/`Timeout` peer may have closed the
    /// socket — a fresh connection re-enters the accept path cleanly).
    fn book_retry(&mut self, attempt: u32) -> Result<()> {
        let policy = self.retry.expect("wants_retry checked the policy");
        self.retries_attempted += 1;
        std::thread::sleep(policy.delay_for(attempt));
        let stream = TcpStream::connect(self.addr)?;
        stream.set_nodelay(true).ok();
        self.stream = stream;
        Ok(())
    }

    fn take_ids(&mut self, n: u64) -> u64 {
        let base = self.next_id;
        self.next_id = self.next_id.wrapping_add(n);
        base
    }

    /// v1 round trip (784-bit images only).
    pub fn classify(&mut self, image: &Packed) -> Result<WireResponse> {
        let request = encode_request(image)?;
        let mut attempt = 0u32;
        loop {
            self.stream.write_all(&request)?;
            let mut frame = [0u8; 7];
            self.stream.read_exact(&mut frame)?;
            if frame[0] == MAGIC_ERR && self.wants_retry(WireStatus::from_u8(frame[1]), attempt) {
                self.book_retry(attempt)?;
                attempt += 1;
                continue;
            }
            return decode_response(&frame);
        }
    }

    /// v2 round trip for one image.
    pub fn classify_v2(&mut self, image: &Packed, opts: InferOptions) -> Result<WireItem> {
        let mut items = self.classify_batch(std::slice::from_ref(image), opts)?;
        Ok(items.pop().expect("one item per image"))
    }

    /// [`Self::classify_v2`] addressed to a named registry model.
    pub fn classify_model(
        &mut self,
        model: &str,
        image: &Packed,
        opts: InferOptions,
    ) -> Result<WireItem> {
        let mut items =
            self.classify_batch_for(Some(model), std::slice::from_ref(image), opts)?;
        Ok(items.pop().expect("one item per image"))
    }

    /// One batched v2 frame: `images.len()` images in, one response frame
    /// with per-image ids/digits out.
    pub fn classify_batch(
        &mut self,
        images: &[Packed],
        opts: InferOptions,
    ) -> Result<Vec<WireItem>> {
        self.classify_batch_for(None, images, opts)
    }

    /// [`Self::classify_batch`] addressed to a named registry model
    /// (`None` ⇒ the server's default model).
    pub fn classify_batch_for(
        &mut self,
        model: Option<&str>,
        images: &[Packed],
        opts: InferOptions,
    ) -> Result<Vec<WireItem>> {
        let mut attempt = 0u32;
        loop {
            let id = self.take_ids(images.len() as u64);
            // re-encoded per try: a deadline section carries the budget
            // *remaining* at send time, so a retry spends its backoff out
            // of the same end-to-end deadline instead of resetting it
            self.stream
                .write_all(&encode_request_v2_for(images, id, opts, model)?)?;
            let resp = read_response_v2(&mut self.stream)?;
            if self.wants_retry(resp.status, attempt) {
                self.book_retry(attempt)?;
                attempt += 1;
                continue;
            }
            anyhow::ensure!(
                resp.status == WireStatus::Ok,
                "server error: {} (frame id {})",
                resp.status.name(),
                resp.id
            );
            anyhow::ensure!(resp.id == id, "response id {} for request {id}", resp.id);
            anyhow::ensure!(
                resp.items.len() == images.len(),
                "{} items for {} images",
                resp.items.len(),
                images.len()
            );
            return Ok(resp.items);
        }
    }

    /// Pipelined v2: keep up to [`Self::PIPELINE_WINDOW`] single-image
    /// frames in flight on one connection — one in-flight *window* instead
    /// of one round trip per image.  The window is bounded so an
    /// arbitrarily long image list can never wedge both peers against
    /// full TCP buffers (the server answers frame-by-frame and would stop
    /// reading once its send buffer filled).
    pub fn classify_pipelined(
        &mut self,
        images: &[Packed],
        opts: InferOptions,
    ) -> Result<Vec<WireItem>> {
        let base = self.take_ids(images.len() as u64);
        let mut out = Vec::with_capacity(images.len());
        for (i, img) in images.iter().enumerate() {
            let frame = encode_request_v2(std::slice::from_ref(img), base.wrapping_add(i as u64), opts)?;
            self.stream.write_all(&frame)?;
            if i + 1 - out.len() >= Self::PIPELINE_WINDOW {
                self.read_pipelined_item(base, out.len(), &mut out)?;
            }
        }
        while out.len() < images.len() {
            self.read_pipelined_item(base, out.len(), &mut out)?;
        }
        Ok(out)
    }

    /// Read + validate the next pipelined response (request `base + idx`).
    fn read_pipelined_item(&mut self, base: u64, idx: usize, out: &mut Vec<WireItem>) -> Result<()> {
        let want_id = base.wrapping_add(idx as u64);
        let resp = read_response_v2(&mut self.stream)?;
        anyhow::ensure!(
            resp.status == WireStatus::Ok,
            "server error: {} (frame id {})",
            resp.status.name(),
            resp.id
        );
        anyhow::ensure!(resp.id == want_id, "response id {} for request {want_id}", resp.id);
        anyhow::ensure!(resp.items.len() == 1, "{} items for 1 image", resp.items.len());
        out.push(resp.items.into_iter().next().unwrap());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::util::prng::Xoshiro256;

    fn image_of(seed: u64, n_bits: usize) -> Packed {
        let mut rng = Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
        Packed::from_bits(&bits)
    }

    fn image(seed: u64) -> Packed {
        image_of(seed, IMAGE_BITS)
    }

    #[test]
    fn v1_frame_roundtrip() {
        let img = image(1);
        let frame = encode_request(&img).unwrap();
        assert_eq!(frame[0], MAGIC_REQ);
        assert_eq!(frame.len(), 3 + PAYLOAD_BYTES);
        let decoded = decode_payload(&frame[3..]).unwrap();
        assert_eq!(decoded.words, img.words);
    }

    #[test]
    fn v1_rejects_other_widths_instead_of_panicking() {
        let e = encode_request(&image_of(2, 100)).unwrap_err();
        assert!(format!("{e}").contains("v2"), "{e}");
    }

    #[test]
    fn v1_response_roundtrip() {
        let f = encode_response(7, 123_456);
        let r = decode_response(&f).unwrap();
        assert_eq!(r, WireResponse { digit: 7, status: 0, latency_us: 123_456 });
        assert!(decode_response(&encode_error(WireStatus::Backend)).is_err());
        assert!(decode_response(&[0u8; 7]).is_err());
    }

    #[test]
    fn payload_layout_is_lsb_first_bytes() {
        // the word-level fast path must serialize exactly the documented
        // bit-i-at-byte-i/8-bit-i%8 layout (per-bit reference built here)
        for n_bits in [1usize, 7, 8, 63, 64, 65, 77, 784] {
            let img = image_of(60 + n_bits as u64, n_bits);
            let payload = bits_to_payload(&img);
            let bits = img.to_bits();
            let mut want = vec![0u8; payload_bytes(n_bits)];
            for (i, &b) in bits.iter().enumerate() {
                want[i / 8] |= b << (i % 8);
            }
            assert_eq!(payload, want, "width {n_bits}");
            let back = payload_to_packed(&payload, n_bits).unwrap();
            assert_eq!(back.words, img.words, "width {n_bits}");
            assert_eq!(back.n_bits, n_bits);
        }
        // dirty padding in a hand-built Packed must not leak onto the wire
        let dirty = Packed { words: vec![u64::MAX], n_bits: 5 };
        assert_eq!(bits_to_payload(&dirty), vec![0b0001_1111u8]);
    }

    #[test]
    fn v1_payloads_hardened_against_bad_sizes() {
        let truncated = decode_payload(&[0u8; 10]).unwrap_err();
        assert!(format!("{truncated}").contains("truncated"), "{truncated}");
        let oversized = decode_payload(&[0u8; 200]).unwrap_err();
        assert!(format!("{oversized}").contains("oversized"), "{oversized}");
    }

    #[test]
    fn v2_request_roundtrip_all_sections() {
        let imgs = vec![image_of(3, 65), image_of(4, 65), image_of(5, 65)];
        let opts = InferOptions::default().with_top_k(3);
        let frame = encode_request_v2(&imgs, 42, opts).unwrap();
        assert_eq!(frame[0], MAGIC_REQ_V2);
        let mut cur = std::io::Cursor::new(&frame[1..]);
        let req = read_request_v2_body(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, frame.len() - 1, "frame fully consumed");
        assert_eq!(req.id, 42);
        assert_eq!(req.opts, opts);
        assert_eq!(req.images.len(), 3);
        for (a, b) in req.images.iter().zip(&imgs) {
            assert_eq!(a.n_bits, b.n_bits);
            assert_eq!(a.words, b.words);
        }
    }

    #[test]
    fn v2_model_name_section_roundtrip_and_validation() {
        let imgs = vec![image_of(30, 64)];
        // plain frames carry no name and decode to model: None
        let frame = encode_request_v2(&imgs, 7, InferOptions::default()).unwrap();
        assert_eq!(frame[1] & FEAT_MODEL, 0);
        let req = read_request_v2_body(&mut std::io::Cursor::new(&frame[1..])).unwrap();
        assert_eq!(req.model, None);

        // named frames round-trip the name and stay fully consumed
        let frame =
            encode_request_v2_for(&imgs, 7, InferOptions::default().with_top_k(1), Some("mnist-a"))
                .unwrap();
        assert_ne!(frame[1] & FEAT_MODEL, 0);
        let mut cur = std::io::Cursor::new(&frame[1..]);
        let req = read_request_v2_body(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, frame.len() - 1, "frame fully consumed");
        assert_eq!(req.model.as_deref(), Some("mnist-a"));
        assert_eq!(req.images[0].words, imgs[0].words);

        // encode-side limits: empty and oversized names refuse to encode
        assert!(encode_request_v2_for(&imgs, 1, InferOptions::default(), Some("")).is_err());
        let long = "m".repeat(MAX_MODEL_NAME + 1);
        assert!(encode_request_v2_for(&imgs, 1, InferOptions::default(), Some(&long)).is_err());
        // read-side: a hand-built frame with a 0-length or oversized name
        // section is a typed error, and bad UTF-8 never becomes a String
        let good = encode_request_v2_for(&imgs, 9, InferOptions::default(), Some("ab")).unwrap();
        let mut zero = good.clone();
        zero[17] = 0; // name_len byte
        let e = read_request_v2_body(&mut std::io::Cursor::new(&zero[1..])).unwrap_err();
        assert_eq!(e.status, WireStatus::BadLength, "{e}");
        let mut oversized = good.clone();
        oversized[17] = (MAX_MODEL_NAME + 1) as u8;
        let e = read_request_v2_body(&mut std::io::Cursor::new(&oversized[1..])).unwrap_err();
        assert_eq!(e.status, WireStatus::TooLarge, "{e}");
        let mut bad_utf8 = good;
        bad_utf8[18] = 0xFF;
        bad_utf8[19] = 0xFE;
        let e = read_request_v2_body(&mut std::io::Cursor::new(&bad_utf8[1..])).unwrap_err();
        assert_eq!(e.status, WireStatus::BadLength, "{e}");
    }

    #[test]
    fn submit_errors_map_to_typed_statuses() {
        let s = |msg: &str| submit_error_status(&anyhow::anyhow!("{msg}"));
        assert_eq!(s("queue full (64 queued, cap 64)"), WireStatus::Overloaded);
        assert_eq!(s("shard 3 full (16 requests, cap 16)"), WireStatus::Overloaded);
        assert_eq!(
            s("model mnist-a quota exceeded (8 requests in flight)"),
            WireStatus::Overloaded
        );
        assert_eq!(s("unknown model 'nope' (have: [\"mnist\"])"), WireStatus::UnknownModel);
        assert_eq!(s("image width 65 does not match model width 784"), WireStatus::Backend);
        assert_eq!(
            s("request 12 failed: deadline exceeded before a worker picked it up"),
            WireStatus::DeadlineExceeded
        );
        assert_eq!(
            s("request 12 failed: worker crashed while executing the batch"),
            WireStatus::WorkerCrashed
        );
    }

    #[test]
    fn new_statuses_roundtrip_the_byte_codec() {
        for s in [WireStatus::DeadlineExceeded, WireStatus::WorkerCrashed] {
            assert_eq!(WireStatus::from_u8(s as u8), s);
        }
        assert_eq!(WireStatus::DeadlineExceeded.name(), "deadline-exceeded");
        assert_eq!(WireStatus::WorkerCrashed.name(), "worker-crashed");
    }

    #[test]
    fn v2_deadline_section_roundtrips_a_relative_budget() {
        let imgs = vec![image_of(40, 64), image_of(41, 64)];
        let opts = InferOptions::default().with_budget(std::time::Duration::from_millis(250));
        let frame = encode_request_v2(&imgs, 11, opts).unwrap();
        assert_ne!(frame[1] & FEAT_DEADLINE, 0);
        let mut cur = std::io::Cursor::new(&frame[1..]);
        let before = std::time::Instant::now();
        let req = read_request_v2_body(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, frame.len() - 1, "frame fully consumed");
        assert_eq!(req.images.len(), 2);
        // the decoded deadline re-arms against the *reader's* clock: it
        // lands within ~(0, 250ms] of the read, whatever the encode took
        // (small slack: encode and read each take their own `now`)
        let d = req.opts.deadline.expect("deadline armed");
        let remaining = d.saturating_duration_since(before);
        assert!(remaining <= std::time::Duration::from_millis(260), "{remaining:?}");
        assert!(remaining > std::time::Duration::ZERO, "budget did not survive");

        // the section also composes with a model name (name first)
        let named = encode_request_v2_for(&imgs, 12, opts, Some("mnist-a")).unwrap();
        let req = read_request_v2_body(&mut std::io::Cursor::new(&named[1..])).unwrap();
        assert_eq!(req.model.as_deref(), Some("mnist-a"));
        assert!(req.opts.deadline.is_some());

        // an already-expired deadline still encodes (budget 0) — the
        // server sheds it with a typed status instead of the client
        // failing to build a frame
        let expired = InferOptions::default()
            .with_deadline(std::time::Instant::now() - std::time::Duration::from_secs(1));
        let frame = encode_request_v2(&imgs, 13, expired).unwrap();
        let req = read_request_v2_body(&mut std::io::Cursor::new(&frame[1..])).unwrap();
        assert!(req.opts.expired_at(std::time::Instant::now() + std::time::Duration::from_millis(1)));

        // a truncated deadline section is a typed error, not a hang
        let frame = encode_request_v2(&[image_of(42, 64)], 14, opts).unwrap();
        let cut = 1 + 16 + 2; // magic + head + half the budget bytes
        let e = read_request_v2_body(&mut std::io::Cursor::new(&frame[1..cut])).unwrap_err();
        assert_eq!(e.status, WireStatus::BadLength, "{e}");
    }

    #[test]
    fn retry_policy_schedule_is_deterministic_and_bounded() {
        let p = RetryPolicy {
            max_attempts: 5,
            base: std::time::Duration::from_millis(1),
            cap: std::time::Duration::from_millis(4),
            seed: 7,
        };
        let schedule: Vec<_> = (0..4).map(|a| p.delay_for(a)).collect();
        // pure in (seed, attempt): the exact schedule reproduces
        assert_eq!(schedule, (0..4).map(|a| p.delay_for(a)).collect::<Vec<_>>());
        for (a, d) in schedule.iter().enumerate() {
            // backoff = min(base << a, cap), jitter ∈ [0, backoff/2]
            let backoff = std::time::Duration::from_millis((1u64 << a).min(4));
            assert!(*d >= backoff, "attempt {a}: {d:?} < {backoff:?}");
            assert!(*d <= backoff + backoff / 2, "attempt {a}: {d:?}");
        }
        // a different seed jitters differently (overwhelmingly likely)
        let q = RetryPolicy { seed: 8, ..p };
        assert_ne!(
            (0..4).map(|a| p.delay_for(a)).collect::<Vec<_>>(),
            (0..4).map(|a| q.delay_for(a)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn client_retries_overloaded_until_the_server_recovers() {
        use std::sync::atomic::AtomicUsize;

        // mock server: answers the first two v2 frames Overloaded (closing
        // the connection each time, like a shed under pressure), then
        // serves for real
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hits = Arc::new(AtomicUsize::new(0));
        let t_hits = hits.clone();
        let server = std::thread::spawn(move || {
            for n in 0.. {
                let (mut s, _) = match listener.accept() {
                    Ok(x) => x,
                    Err(_) => return,
                };
                let mut magic = [0u8; 1];
                if s.read_exact(&mut magic).is_err() {
                    return;
                }
                assert_eq!(magic[0], MAGIC_REQ_V2);
                let req = read_request_v2_body(&mut s).unwrap();
                t_hits.fetch_add(1, Ordering::SeqCst);
                if n < 2 {
                    let _ = s.write_all(&encode_error_v2(req.id, WireStatus::Overloaded));
                    // connection drops here — the retry must reconnect
                } else {
                    let items = vec![WireItem {
                        id: req.id,
                        digit: 7,
                        latency_us: 1,
                        logits: vec![],
                        top_k: vec![],
                    }];
                    let frame =
                        encode_response_v2(req.id, WireStatus::Ok, 0, 0, &items).unwrap();
                    let _ = s.write_all(&frame);
                    return;
                }
            }
        });

        let policy = RetryPolicy {
            max_attempts: 4,
            base: std::time::Duration::from_micros(100),
            cap: std::time::Duration::from_millis(2),
            seed: 1,
        };
        let mut client = WireClient::connect(addr).unwrap().with_retry(policy);
        let item = client
            .classify_v2(&image_of(50, 64), InferOptions::digits_only())
            .unwrap();
        assert_eq!(item.digit, 7);
        assert_eq!(client.retries_attempted(), 2, "two sheds, two retries");
        assert_eq!(hits.load(Ordering::SeqCst), 3, "three tries total");
        server.join().unwrap();
    }

    #[test]
    fn client_without_a_policy_surfaces_overload_immediately() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut magic = [0u8; 1];
            s.read_exact(&mut magic).unwrap();
            let req = read_request_v2_body(&mut s).unwrap();
            let _ = s.write_all(&encode_error_v2(req.id, WireStatus::Overloaded));
        });
        let mut client = WireClient::connect(addr).unwrap();
        let e = client
            .classify_v2(&image_of(51, 64), InferOptions::digits_only())
            .unwrap_err();
        assert!(format!("{e}").contains("overloaded"), "{e}");
        assert_eq!(client.retries_attempted(), 0);
        server.join().unwrap();
    }

    #[test]
    fn v2_request_validation() {
        assert!(encode_request_v2(&[], 1, InferOptions::default()).is_err());
        // mixed widths refuse to encode
        let mixed = vec![image_of(6, 64), image_of(7, 63)];
        assert!(encode_request_v2(&mixed, 1, InferOptions::default()).is_err());
        // absurd top-k refuses to encode
        let one = vec![image_of(8, 64)];
        assert!(encode_request_v2(&one, 1, InferOptions::default().with_top_k(0)).is_err());
        assert!(encode_request_v2(&one, 1, InferOptions::default().with_top_k(300)).is_err());
    }

    #[test]
    fn v2_response_roundtrip_with_and_without_sections() {
        let items = vec![
            WireItem { id: 9, digit: 3, latency_us: 17, logits: vec![1, -2, 3], top_k: vec![(2, 3), (0, 1)] },
            WireItem { id: 10, digit: 0, latency_us: 1, logits: vec![5, 4, -9], top_k: vec![(0, 5), (1, 4)] },
        ];
        let frame = encode_response_v2(9, WireStatus::Ok, FEAT_LOGITS | FEAT_TOPK, 2, &items).unwrap();
        let mut cur = std::io::Cursor::new(frame.as_slice());
        let resp = read_response_v2(&mut cur).unwrap();
        assert_eq!(cur.position() as usize, frame.len());
        assert_eq!(resp.status, WireStatus::Ok);
        assert_eq!(resp.id, 9);
        assert_eq!(resp.items, items);

        // digit-only response: no logits/top-k bytes on the wire at all
        let bare = vec![WireItem { id: 1, digit: 7, latency_us: 2, logits: vec![], top_k: vec![] }];
        let frame = encode_response_v2(1, WireStatus::Ok, 0, 0, &bare).unwrap();
        assert_eq!(frame.len(), 14 + 14);

        // a >255-class digit survives the round trip unwrapped
        let wide = vec![WireItem {
            id: 2,
            digit: 399,
            latency_us: 5,
            logits: vec![],
            top_k: vec![],
        }];
        let frame = encode_response_v2(2, WireStatus::Ok, 0, 0, &wide).unwrap();
        let resp = read_response_v2(&mut std::io::Cursor::new(frame.as_slice())).unwrap();
        assert_eq!(resp.items, wide);
        let resp = read_response_v2(&mut std::io::Cursor::new(frame.as_slice())).unwrap();
        assert_eq!(resp.items, bare);

        // error frame decodes to a typed status with zero items
        let err = encode_error_v2(77, WireStatus::TooLarge);
        let resp = read_response_v2(&mut std::io::Cursor::new(err.as_slice())).unwrap();
        assert_eq!(resp.status, WireStatus::TooLarge);
        assert_eq!(resp.id, 77);
        assert!(resp.items.is_empty());
    }

    #[test]
    fn encoder_enforces_the_read_side_limits() {
        let big = WireItem {
            id: 1,
            digit: 0,
            latency_us: 0,
            logits: vec![0; MAX_WIRE_CLASSES + 1],
            top_k: vec![],
        };
        assert!(
            encode_response_v2(1, WireStatus::Ok, FEAT_LOGITS, 0, std::slice::from_ref(&big))
                .is_err()
        );
        // without FEAT_LOGITS the oversize vector is never serialized
        assert!(encode_response_v2(1, WireStatus::Ok, 0, 0, std::slice::from_ref(&big)).is_ok());
        let many_topk = WireItem {
            id: 1,
            digit: 0,
            latency_us: 0,
            logits: vec![],
            top_k: vec![(0, 0); 256],
        };
        assert!(encode_response_v2(1, WireStatus::Ok, FEAT_TOPK, 0, &[many_topk]).is_err());
    }

    #[test]
    fn v2_truncated_frames_give_typed_errors() {
        let imgs = vec![image_of(11, 784)];
        let frame = encode_request_v2(&imgs, 5, InferOptions::default()).unwrap();
        for cut in [1usize, 8, 16, frame.len() - 1] {
            let mut cur = std::io::Cursor::new(&frame[1..cut]);
            let e = read_request_v2_body(&mut cur).unwrap_err();
            assert_eq!(e.status, WireStatus::BadLength, "cut at {cut}: {e}");
        }
        let resp = encode_response_v2(5, WireStatus::Ok, FEAT_LOGITS, 0, &[WireItem {
            id: 5, digit: 1, latency_us: 3, logits: vec![1, 2], top_k: vec![],
        }])
        .unwrap();
        for cut in [2usize, 13, resp.len() - 1] {
            let mut cur = std::io::Cursor::new(&resp[..cut]);
            let e = read_response_v2(&mut cur).unwrap_err();
            assert_eq!(e.status, WireStatus::BadLength, "cut at {cut}: {e}");
        }
    }

    #[test]
    fn v2_header_validation_is_typed() {
        // hand-crafted headers (after the magic byte):
        // features, top_k, id[8], n_images[2], n_bits[4]
        let head = |features: u8, top_k: u8, n_images: u16, n_bits: u32| -> Vec<u8> {
            let mut h = vec![features, top_k];
            h.extend_from_slice(&99u64.to_le_bytes());
            h.extend_from_slice(&n_images.to_le_bytes());
            h.extend_from_slice(&n_bits.to_le_bytes());
            h
        };
        let cases = [
            (head(0x80, 0, 1, 64), WireStatus::BadFeature),
            (head(FEAT_TOPK, 0, 1, 64), WireStatus::BadFeature),
            (head(0, 0, 0, 64), WireStatus::BadLength),
            (head(0, 0, u16::MAX, 64), WireStatus::TooLarge),
            (head(0, 0, 1, 0), WireStatus::BadLength),
            (head(0, 0, 1, u32::MAX), WireStatus::TooLarge),
        ];
        for (bytes, want) in cases {
            let e = read_request_v2_body(&mut std::io::Cursor::new(bytes.as_slice())).unwrap_err();
            assert_eq!(e.status, want, "{e}");
            assert_eq!(e.id, Some(99), "id still echoed: {e}");
        }
    }

    #[test]
    fn tcp_end_to_end_v1_and_v2_against_one_server() {
        use crate::bnn::model::random_model;
        use crate::coordinator::Kernel;

        let model = random_model(&[784, 128, 64, 10], 5);
        let engine = Arc::new(
            Engine::builder()
                .native(&model)
                .kernel(Kernel::default())
                .workers(2)
                .build()
                .unwrap(),
        );
        let server = WireServer::start("127.0.0.1:0", engine).unwrap();
        let mut client = WireClient::connect(server.addr).unwrap();
        // v1 and v2 single-image classifies agree with direct inference
        for seed in 0..4 {
            let img = image(seed);
            let r1 = client.classify(&img).unwrap();
            assert_eq!(r1.digit as usize, model.predict(&img.words), "v1 seed {seed}");
            assert_eq!(r1.status, 0);
            let r2 = client.classify_v2(&img, InferOptions::default().with_top_k(2)).unwrap();
            assert_eq!(r2.digit, r1.digit as u16, "v2 seed {seed}");
            assert_eq!(r2.logits, model.logits(&img.words));
            assert_eq!(r2.top_k.len(), 2);
            assert_eq!(r2.top_k[0].0, r2.digit);
        }
        // one batched frame: per-image ids and digits
        let batch: Vec<Packed> = (10..17).map(image).collect();
        let items = client.classify_batch(&batch, InferOptions::digits_only()).unwrap();
        assert_eq!(items.len(), batch.len());
        for (i, (item, img)) in items.iter().zip(&batch).enumerate() {
            assert_eq!(item.id, items[0].id + i as u64, "ids are base + index");
            assert_eq!(item.digit as usize, model.predict(&img.words));
            assert!(item.logits.is_empty(), "digits_only carries no logits");
        }
        assert_eq!(
            server.served.load(Ordering::Relaxed),
            4 * 2 + batch.len() as u64
        );
        server.shutdown();
    }

    #[test]
    fn tcp_v2_serves_non_784_widths() {
        use crate::bnn::model::random_model;

        // a 65-bit model: v2 carries the width, v1 cannot
        let model = random_model(&[65, 32, 10], 6);
        let engine = Arc::new(Engine::builder().native(&model).workers(1).build().unwrap());
        let server = WireServer::start("127.0.0.1:0", engine).unwrap();
        let mut client = WireClient::connect(server.addr).unwrap();
        for seed in 20..24 {
            let img = image_of(seed, 65);
            let item = client.classify_v2(&img, InferOptions::default()).unwrap();
            assert_eq!(item.digit as usize, model.predict(&img.words), "seed {seed}");
            assert_eq!(item.logits, model.logits(&img.words));
        }
        // a 784-bit v1 frame against the 65-bit model is a clean backend
        // error, not a dead worker: the v2 path keeps serving after it
        assert!(client.classify(&image(25)).is_err());
        let img = image_of(26, 65);
        let item = client.classify_v2(&img, InferOptions::default()).unwrap();
        assert_eq!(item.digit as usize, model.predict(&img.words));
        server.shutdown();
    }
}
