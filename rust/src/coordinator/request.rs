//! Request/response types flowing through the coordinator.

use std::time::Instant;

use crate::bnn::packing::Packed;

/// Monotonically increasing request id (assigned by the coordinator).
pub type RequestId = u64;

/// One classification request: a packed 784-bit binarized image.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub image: Packed,
    pub enqueued_at: Instant,
}

impl InferRequest {
    pub fn new(id: RequestId, image: Packed) -> Self {
        Self {
            id,
            image,
            enqueued_at: Instant::now(),
        }
    }
}

/// The classified result.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: RequestId,
    pub digit: u8,
    pub logits: Vec<i32>,
    /// Queue + batch + execute time, nanoseconds.
    pub latency_ns: u64,
    /// Batch this request was executed in (observability).
    pub batch_size: usize,
    pub backend: &'static str,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_bits_u64;

    #[test]
    fn request_captures_enqueue_time() {
        let img = Packed {
            words: pack_bits_u64(&vec![0u8; 784]),
            n_bits: 784,
        };
        let r = InferRequest::new(7, img);
        assert_eq!(r.id, 7);
        assert!(r.enqueued_at.elapsed().as_secs() < 1);
    }
}
