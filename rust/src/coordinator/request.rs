//! Request/response types flowing through the coordinator, plus the
//! [`Ticket`] handle every `submit` returns.
//!
//! The PR 4 API redesign made three things first-class here:
//!
//! * [`InferOptions`] — per-request knobs (full logits on/off, top-k),
//!   carried end to end: wire frame → [`InferRequest`] → response assembly
//!   in `pool::execute_batch`;
//! * [`Ticket`] — the submit handle.  Callers never see the underlying
//!   `mpsc::Receiver`; they `wait()`, `wait_timeout()` or `try_poll()` the
//!   ticket, and dropping it unresolved counts into `Metrics::cancelled`
//!   (drop-to-cancel accounting — the batch may still execute, but the
//!   abandonment is visible in the books);
//! * [`top_k_i32`] — the shared top-k selection both the response builder
//!   and the wire layer agree on.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::metrics::Metrics;
use crate::bnn::packing::Packed;

/// Monotonically increasing request id (assigned by the serving engine).
pub type RequestId = u64;

/// Typed terminal failure a worker can deliver on a reply channel instead
/// of a response.  Distinct from a *disconnected* channel (the sender was
/// dropped — queued work abandoned at shutdown, or a worker that died
/// without supervision): a `Failure` is an **answered** request, so the
/// ticket resolves with a precise error instead of the generic
/// "dropped by the backend".  The error messages carry fixed substrings
/// ("worker crashed", "deadline exceeded") that
/// `wire::submit_error_status` maps onto the wire taxonomy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Failure {
    /// The worker executing this request's batch panicked; the supervisor
    /// resolved the batch and restarted the worker (`Metrics::worker_restarts`).
    WorkerCrashed,
    /// The request's [`InferOptions::deadline`] passed before execution —
    /// shed by the batcher or the worker's dequeue check, never executed.
    DeadlineExceeded,
}

impl Failure {
    /// Stable substring the wire layer keys its status mapping on.
    pub fn as_str(self) -> &'static str {
        match self {
            Failure::WorkerCrashed => "worker crashed",
            Failure::DeadlineExceeded => "deadline exceeded",
        }
    }
}

/// What flows down a reply channel: a response, or a typed failure.
pub(crate) type Reply = std::result::Result<InferResponse, Failure>;

/// Per-request serving options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InferOptions {
    /// Include the full logits vector in the response.  Turning this off
    /// drops the per-request `n_classes` heap copy — the digit is still
    /// computed from the worker's flat arena.
    pub include_logits: bool,
    /// Also return the best `k` `(class, logit)` pairs, best first (ties
    /// toward the lower class index, matching [`crate::bnn::argmax_i32`]).
    pub top_k: Option<usize>,
    /// Absolute point after which the request is worthless: the batcher
    /// sheds it before launch and workers re-check on dequeue, answering
    /// [`Failure::DeadlineExceeded`] instead of burning compute on a reply
    /// nobody is waiting for.  Carried on the wire as a relative budget
    /// (`FEAT_DEADLINE`, µs) and re-anchored to the server's clock.
    pub deadline: Option<Instant>,
}

impl Default for InferOptions {
    fn default() -> Self {
        Self {
            include_logits: true,
            top_k: None,
            deadline: None,
        }
    }
}

impl InferOptions {
    /// Digit-only responses: no logits copy, no top-k section.
    pub fn digits_only() -> Self {
        Self {
            include_logits: false,
            top_k: None,
            deadline: None,
        }
    }

    /// Request the best `k` `(class, logit)` pairs.
    pub fn with_top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    /// Toggle the full logits vector.
    pub fn with_logits(mut self, include: bool) -> Self {
        self.include_logits = include;
        self
    }

    /// Set an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set the deadline as a budget from now.
    pub fn with_budget(self, budget: Duration) -> Self {
        self.with_deadline(Instant::now() + budget)
    }

    /// Whether the deadline (if any) has passed at `now`.
    pub fn expired_at(&self, now: Instant) -> bool {
        matches!(self.deadline, Some(d) if now >= d)
    }
}

/// Top-k `(class, logit)` pairs of one logits row, best first; ties break
/// toward the lower class index (so `top_k_i32(row, 1)[0].0 as usize` is
/// exactly `argmax_i32(row)`).  Class ids are u16 — wide enough for the
/// wire protocol's `MAX_WIRE_CLASSES` (4096), so no silent truncation.
pub fn top_k_i32(scores: &[i32], k: usize) -> Vec<(u16, i32)> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(k.min(scores.len()));
    idx.into_iter().map(|i| (i as u16, scores[i])).collect()
}

/// One classification request: a packed binarized image + options.
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub image: Packed,
    pub opts: InferOptions,
    pub enqueued_at: Instant,
}

impl InferRequest {
    pub fn new(id: RequestId, image: Packed) -> Self {
        Self::with_opts(id, image, InferOptions::default())
    }

    pub fn with_opts(id: RequestId, image: Packed, opts: InferOptions) -> Self {
        Self {
            id,
            image,
            opts,
            enqueued_at: Instant::now(),
        }
    }
}

/// The classified result.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: RequestId,
    /// Winning class id.  u16 like the top-k class carrier: a >255-class
    /// model must never wrap its argmax (`MAX_WIRE_CLASSES` is 4096).
    pub digit: u16,
    /// Full logits row (empty when the request set `include_logits: false`).
    pub logits: Vec<i32>,
    /// Top-k `(class, logit)` pairs, best first (empty unless requested).
    pub top_k: Vec<(u16, i32)>,
    /// Queue + batch + execute time, nanoseconds.
    pub latency_ns: u64,
    /// Time spent queued before execution began, nanoseconds (a component
    /// of `latency_ns`, surfaced so serving front ends can feed their own
    /// queue-wait histograms).
    pub queue_wait_ns: u64,
    /// Batch this request was executed in (observability).
    pub batch_size: usize,
    pub backend: &'static str,
}

/// Handle to one in-flight request.
///
/// Lifecycle:
///
/// ```text
///   submit ──► Ticket ──► wait()/wait_timeout()/try_poll() ──► InferResponse
///                │
///                └─ dropped unresolved ──► Metrics::cancelled += 1
/// ```
///
/// A ticket resolves exactly once: after a response (or a backend-drop
/// error) has been delivered, further polls error out.  Dropping an
/// unresolved ticket is the cancel signal — the engine may still execute
/// the request (its reply then lands in a closed channel), but the
/// abandonment is counted so `submitted == completed + rejected` plus the
/// `cancelled` gauge always tells the whole story.
pub struct Ticket {
    id: RequestId,
    rx: mpsc::Receiver<Reply>,
    metrics: Arc<Metrics>,
    resolved: bool,
    /// Fired exactly once when the ticket leaves the system (resolved or
    /// dropped) — the model registry hangs per-model in-flight accounting
    /// here so quotas release no matter how the caller finishes.
    observer: Option<Box<dyn FnOnce() + Send>>,
}

impl Ticket {
    pub(crate) fn new(
        id: RequestId,
        rx: mpsc::Receiver<Reply>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self {
            id,
            rx,
            metrics,
            resolved: false,
            observer: None,
        }
    }

    /// Attach a completion observer, fired exactly once on resolve-or-drop.
    pub(crate) fn with_observer(mut self, f: Box<dyn FnOnce() + Send>) -> Self {
        self.observer = Some(f);
        self
    }

    /// The engine-assigned request id.
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Map a delivered [`Reply`] onto the public result surface.
    fn surface(id: RequestId, reply: Reply) -> Result<InferResponse> {
        match reply {
            Ok(r) => Ok(r),
            Err(f) => bail!("request {id} failed: {}", f.as_str()),
        }
    }

    /// Block until the response arrives, consuming the ticket.
    pub fn wait(mut self) -> Result<InferResponse> {
        self.resolved = true;
        match self.rx.recv() {
            Ok(reply) => Self::surface(self.id, reply),
            Err(_) => bail!(
                "request {} was dropped by the backend (see the rejected counter)",
                self.id
            ),
        }
    }

    /// Wait up to `timeout`.  `Ok(None)` means not ready yet — the ticket
    /// stays live and can be polled again.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Option<InferResponse>> {
        if self.resolved {
            bail!("ticket {} already resolved", self.id);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(reply) => {
                self.resolved = true;
                Self::surface(self.id, reply).map(Some)
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                self.resolved = true;
                bail!(
                    "request {} was dropped by the backend (see the rejected counter)",
                    self.id
                )
            }
        }
    }

    /// Non-blocking poll.  `Ok(None)` means not ready yet.
    pub fn try_poll(&mut self) -> Result<Option<InferResponse>> {
        if self.resolved {
            bail!("ticket {} already resolved", self.id);
        }
        match self.rx.try_recv() {
            Ok(reply) => {
                self.resolved = true;
                Self::surface(self.id, reply).map(Some)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                self.resolved = true;
                bail!(
                    "request {} was dropped by the backend (see the rejected counter)",
                    self.id
                )
            }
        }
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if !self.resolved {
            self.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        }
        // Drop runs exactly once on every exit path (wait() consumes the
        // ticket, so even that falls through here), which makes it the one
        // place the observer can fire exactly once.
        if let Some(f) = self.observer.take() {
            f();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_bits_u64;

    fn img() -> Packed {
        Packed {
            words: pack_bits_u64(&vec![0u8; 784]),
            n_bits: 784,
        }
    }

    fn resp(id: RequestId) -> InferResponse {
        InferResponse {
            id,
            digit: 3,
            logits: vec![0; 10],
            top_k: Vec::new(),
            latency_ns: 1,
            queue_wait_ns: 0,
            batch_size: 1,
            backend: "test",
        }
    }

    #[test]
    fn request_captures_enqueue_time_and_default_opts() {
        let r = InferRequest::new(7, img());
        assert_eq!(r.id, 7);
        assert_eq!(r.opts, InferOptions::default());
        assert!(r.opts.include_logits && r.opts.top_k.is_none());
        assert!(r.enqueued_at.elapsed().as_secs() < 1);
    }

    #[test]
    fn top_k_orders_and_breaks_ties_like_argmax() {
        let scores = [5, 9, 9, -1, 7];
        assert_eq!(top_k_i32(&scores, 3), vec![(1, 9), (2, 9), (4, 7)]);
        // k = 1 agrees with argmax; k beyond len truncates
        assert_eq!(top_k_i32(&scores, 1)[0].0 as usize, crate::bnn::argmax_i32(&scores));
        assert_eq!(top_k_i32(&scores, 99).len(), scores.len());
        assert!(top_k_i32(&[], 3).is_empty());
        // class ids above the u8 range survive intact (u16 carrier)
        let mut wide = vec![0i32; 400];
        wide[300] = 7;
        assert_eq!(top_k_i32(&wide, 1), vec![(300, 7)]);
    }

    #[test]
    fn waited_ticket_is_not_counted_cancelled() {
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let t = Ticket::new(1, rx, m.clone());
        tx.send(Ok(resp(1))).unwrap();
        assert_eq!(t.wait().unwrap().id, 1);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn typed_failures_surface_their_substring() {
        // a delivered Failure resolves the ticket with the mapped message
        // (the wire layer's status mapping keys on these substrings)
        for (f, want) in [
            (Failure::WorkerCrashed, "worker crashed"),
            (Failure::DeadlineExceeded, "deadline exceeded"),
        ] {
            let m = Arc::new(Metrics::new());
            let (tx, rx) = mpsc::channel();
            let t = Ticket::new(9, rx, m.clone());
            tx.send(Err(f)).unwrap();
            let e = t.wait().unwrap_err();
            assert!(format!("{e}").contains(want), "{e}");
            // the failure answered the request, so it is not a cancel
            assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
        }
        // try_poll surfaces the same typed error and resolves the ticket
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let mut t = Ticket::new(10, rx, m.clone());
        tx.send(Err(Failure::WorkerCrashed)).unwrap();
        let e = t.try_poll().unwrap_err();
        assert!(format!("{e}").contains("worker crashed"), "{e}");
        drop(t);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn deadline_options_expire_exactly_at_the_instant() {
        let now = Instant::now();
        let opts = InferOptions::default();
        assert!(!opts.expired_at(now), "no deadline never expires");
        let opts = opts.with_deadline(now + Duration::from_micros(100));
        assert!(!opts.expired_at(now));
        assert!(opts.expired_at(now + Duration::from_micros(100)), ">= is expired");
        assert!(opts.expired_at(now + Duration::from_secs(1)));
        // with_budget anchors at call time; a generous budget is not expired
        let opts = InferOptions::digits_only().with_budget(Duration::from_secs(60));
        assert!(!opts.expired_at(Instant::now()));
        assert!(opts.deadline.is_some());
    }

    #[test]
    fn dropped_ticket_counts_cancelled_exactly_once() {
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let t = Ticket::new(2, rx, m.clone());
        drop(t);
        drop(tx);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn try_poll_and_wait_timeout_resolve_once() {
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let mut t = Ticket::new(3, rx, m.clone());
        assert!(t.try_poll().unwrap().is_none(), "nothing sent yet");
        assert!(t
            .wait_timeout(Duration::from_millis(1))
            .unwrap()
            .is_none());
        tx.send(Ok(resp(3))).unwrap();
        let got = t.try_poll().unwrap().expect("response ready");
        assert_eq!(got.id, 3);
        // resolved: further polls error, and drop does not count cancelled
        assert!(t.try_poll().is_err());
        assert!(t.wait_timeout(Duration::from_millis(1)).is_err());
        drop(t);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn observer_fires_exactly_once_on_every_exit_path() {
        use std::sync::atomic::AtomicUsize;
        let fired = Arc::new(AtomicUsize::new(0));
        // resolved via wait()
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let f = fired.clone();
        let t = Ticket::new(1, rx, m.clone())
            .with_observer(Box::new(move || { f.fetch_add(1, Ordering::SeqCst); }));
        tx.send(Ok(resp(1))).unwrap();
        t.wait().unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // dropped unresolved
        let (_tx2, rx2) = mpsc::channel::<Reply>();
        let f = fired.clone();
        let t = Ticket::new(2, rx2, m.clone())
            .with_observer(Box::new(move || { f.fetch_add(1, Ordering::SeqCst); }));
        drop(t);
        assert_eq!(fired.load(Ordering::SeqCst), 2);
        // resolved via try_poll, then dropped: still once
        let (tx3, rx3) = mpsc::channel();
        let f = fired.clone();
        let mut t = Ticket::new(3, rx3, m)
            .with_observer(Box::new(move || { f.fetch_add(1, Ordering::SeqCst); }));
        tx3.send(Ok(resp(3))).unwrap();
        t.try_poll().unwrap().unwrap();
        drop(t);
        assert_eq!(fired.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn disconnected_ticket_errors_but_is_not_cancelled() {
        // backend dropped the reply (rejected batch): wait errors, and the
        // abandonment is the server's rejected counter, not a client cancel
        let m = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Reply>();
        drop(tx);
        let t = Ticket::new(4, rx, m.clone());
        assert!(t.wait().is_err());
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
    }
}
