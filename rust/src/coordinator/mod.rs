//! L3 serving coordinator — the request-path system around the accelerator.
//!
//! Architecture (vLLM-router-shaped, sized to this paper's workload):
//!
//! ```text
//!   clients ──► Router ──► per-backend DynamicBatcher ──► worker threads
//!                │                (queue + deadline)          │
//!                └──────────────◄── responses ◄───────────────┘
//! ```
//!
//! * [`request`] — request/response types with timing capture;
//! * [`backend`] — the pluggable inference engines: native bit-packed Rust
//!   ([`backend::NativeBackend`]), AOT PJRT artifacts
//!   ([`backend::PjrtBackend`]), and the cycle-accurate FPGA simulator
//!   ([`backend::SimBackend`]) — all proven prediction-equivalent in
//!   `rust/tests/integration.rs`;
//! * [`batcher`] — dynamic batching: drain-until(max_batch | deadline),
//!   ladder-aware batch sizing for the fixed-shape PJRT artifacts;
//! * [`router`] — named-backend routing with a least-queue-depth policy;
//! * [`metrics`] — counters + log-bucket latency histograms;
//! * [`server`] — worker threads and the blocking/async submission API.
//!
//! Python never appears here: the hot path is pure Rust + compiled HLO.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod wire;

pub use backend::{InferBackend, NativeBackend, PjrtBackend, SimBackend};
pub use batcher::BatcherConfig;
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse};
pub use router::Router;
pub use server::Coordinator;
