//! L3 serving coordinator — the request-path system around the accelerator.
//!
//! Architecture (vLLM-router-shaped, sized to this paper's workload):
//!
//! ```text
//!   clients ──► Router ──► per-backend DynamicBatcher ──► worker threads
//!                │                (queue + deadline)          │
//!                └──────────────◄── responses ◄───────────────┘
//! ```
//!
//! * [`request`] — request/response types with timing capture;
//! * [`backend`] — the pluggable inference engines: native bit-packed Rust
//!   ([`backend::NativeBackend`], kernel schedule selected by
//!   [`backend::Kernel`]), AOT PJRT artifacts ([`backend::PjrtBackend`]),
//!   and the cycle-accurate FPGA simulator ([`backend::SimBackend`]) — all
//!   proven prediction-equivalent in `rust/tests/integration.rs`.  Batches
//!   execute into caller-owned [`backend::LogitsBuf`] arenas (flat
//!   `batch × n_classes` logits) with per-worker [`backend::InferScratch`]
//!   reuse, so the steady-state serve path is allocation-free;
//! * [`batcher`] — dynamic batching: drain-until(max_batch | deadline),
//!   ladder-aware batch sizing for the fixed-shape PJRT artifacts;
//! * [`router`] — named-backend routing with a least-queue-depth policy;
//! * [`metrics`] — counters + log-bucket latency histograms;
//! * [`server`] — the single-queue [`Coordinator`]: N worker threads
//!   draining one shared queue into one backend;
//! * [`pool`] — the sharded [`WorkerPool`]: one queue shard + one backend
//!   **replica** + per-worker metrics per worker thread (DESIGN.md
//!   §Worker pool), the scaling path;
//! * [`wire`] — byte-framed TCP server, generic over [`InferService`].
//!
//! Python never appears here: the hot path is pure Rust + compiled HLO.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod server;
pub mod wire;

pub use backend::{
    InferBackend, InferScratch, Kernel, LogitsBuf, NativeBackend, PjrtBackend, SimBackend,
};
pub use batcher::BatcherConfig;
pub use metrics::Metrics;
pub use pool::WorkerPool;
pub use request::{InferRequest, InferResponse};
pub use router::Router;
pub use server::Coordinator;

use crate::bnn::packing::Packed;

/// A serving frontend: anything requests can be submitted to.  Implemented
/// by the single-queue [`Coordinator`] and the sharded [`WorkerPool`];
/// the wire server and load drivers are generic over it.
pub trait InferService: Send + Sync {
    /// Enqueue one image; returns the receiver for its response.
    fn submit(
        &self,
        image: Packed,
    ) -> anyhow::Result<(request::RequestId, std::sync::mpsc::Receiver<InferResponse>)>;

    /// Blocking classify.
    fn infer(&self, image: Packed) -> anyhow::Result<InferResponse> {
        let (_, rx) = self.submit(image)?;
        Ok(rx.recv()?)
    }

    /// Submit many, wait for all (responses in submission order).
    fn infer_many(&self, images: Vec<Packed>) -> anyhow::Result<Vec<InferResponse>> {
        let rxs: Vec<_> = images
            .into_iter()
            .map(|img| self.submit(img).map(|(_, rx)| rx))
            .collect::<anyhow::Result<_>>()?;
        rxs.into_iter().map(|rx| Ok(rx.recv()?)).collect()
    }
}

impl InferService for Coordinator {
    fn submit(
        &self,
        image: Packed,
    ) -> anyhow::Result<(request::RequestId, std::sync::mpsc::Receiver<InferResponse>)> {
        Coordinator::submit(self, image)
    }
}

impl InferService for WorkerPool {
    fn submit(
        &self,
        image: Packed,
    ) -> anyhow::Result<(request::RequestId, std::sync::mpsc::Receiver<InferResponse>)> {
        WorkerPool::submit(self, image)
    }
}
