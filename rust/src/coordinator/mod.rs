//! L3 serving coordinator — the request-path system around the accelerator.
//!
//! Architecture (vLLM-router-shaped, sized to this paper's workload):
//!
//! ```text
//!   clients ──► Router ──► per-backend Engine ──► worker threads
//!                │           (queue + deadline)       │
//!                └────────◄── Tickets ◄───────────────┘
//! ```
//!
//! * [`engine`] — **the public construction path**: [`Engine`] and its
//!   typed builder (`Engine::builder().native(&model).kernel(..)
//!   .workers(..).batcher(..).queue_cap(..).build()`), wrapping either
//!   serving core;
//! * [`request`] — request/response types with timing capture, per-request
//!   [`InferOptions`] (top-k, logits on/off) and the [`Ticket`] submit
//!   handle (wait/poll/drop-to-cancel);
//! * [`backend`] — the pluggable inference engines: native bit-packed Rust
//!   ([`backend::NativeBackend`], kernel schedule selected by
//!   [`backend::Kernel`]), AOT PJRT artifacts ([`backend::PjrtBackend`]),
//!   and the cycle-accurate FPGA simulator ([`backend::SimBackend`]) — all
//!   proven prediction-equivalent in `rust/tests/integration.rs`.  Batches
//!   execute into caller-owned [`backend::LogitsBuf`] arenas (flat
//!   `batch × n_classes` logits) with per-worker [`backend::InferScratch`]
//!   reuse, so the steady-state serve path is allocation-free;
//! * [`batcher`] — dynamic batching: drain-until(max_batch | deadline),
//!   ladder-aware batch sizing for the fixed-shape PJRT artifacts;
//! * [`router`] — named-engine routing with a least-queue-depth policy;
//! * [`metrics`] — counters + log-bucket latency histograms;
//! * [`server`] — the single-queue [`server::Coordinator`] core: N worker
//!   threads draining one shared queue into one backend;
//! * [`pool`] — the sharded [`pool::WorkerPool`] core: one queue shard +
//!   one backend **replica** + per-worker metrics per worker thread
//!   (DESIGN.md §Worker pool), the scaling path;
//! * [`wire`] — byte-framed TCP server speaking protocol v1 (fixed
//!   784-bit frames) and v2 (versioned, variable-width, batched, with
//!   client-supplied ids and optional logits/top-k sections), generic over
//!   [`InferService`];
//! * [`async_wire`] — the readiness-polled (epoll/poll via the vendored
//!   `netpoll` crate) high-fanout server: same protocols, thousands of
//!   connections multiplexed onto one event-loop thread (DESIGN.md
//!   §Async serving);
//! * [`loadgen`] — open-loop load generator (fixed arrival rate, latency
//!   from scheduled send time) for serving benchmarks;
//! * [`chaos`] — deterministic seeded fault injection ([`ChaosBackend`])
//!   wrapping any backend with per-call error/panic/latency/wrong-shape
//!   faults, the test rig for supervised restarts, deadline sheds and
//!   client retries (DESIGN.md §Fault tolerance).
//!
//! Python never appears here: the hot path is pure Rust + compiled HLO.

pub mod async_wire;
pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod server;
pub mod wire;

pub use backend::{
    InferBackend, InferScratch, Kernel, LogitsBuf, NativeBackend, PjrtBackend, SimBackend,
};
pub use batcher::BatcherConfig;
pub use chaos::{ChaosBackend, ChaosConfig, FaultKind};
pub use engine::{BackendSpec, Engine, EngineBuilder};
pub use metrics::Metrics;
pub use pool::RestartPolicy;
pub use request::{Failure, InferOptions, InferRequest, InferResponse, RequestId, Ticket};
pub use router::{ModelRegistry, Router};
pub use server::DEFAULT_QUEUE_CAP;
pub use async_wire::AsyncWireServer;
pub use loadgen::{run_open_loop, LoadConfig, LoadReport};
pub use wire::{RetryPolicy, WireClient, WireServer, WireServerConfig, WireStatus};

use crate::bnn::packing::Packed;

/// A serving frontend: anything requests can be submitted to.  Implemented
/// by [`Engine`] (the public construction path) and by the underlying
/// [`server::Coordinator`]/[`pool::WorkerPool`] cores; the wire server and
/// load drivers are generic over it.  Channel internals never leak: every
/// submit returns a [`Ticket`].
pub trait InferService: Send + Sync {
    /// Enqueue one image with explicit per-request options.
    fn submit_with(&self, image: Packed, opts: InferOptions) -> anyhow::Result<Ticket>;

    /// Enqueue one image with default options.
    fn submit(&self, image: Packed) -> anyhow::Result<Ticket> {
        self.submit_with(image, InferOptions::default())
    }

    /// Blocking classify.
    fn infer(&self, image: Packed) -> anyhow::Result<InferResponse> {
        self.submit(image)?.wait()
    }

    /// Blocking classify with options.
    fn infer_with(&self, image: Packed, opts: InferOptions) -> anyhow::Result<InferResponse> {
        self.submit_with(image, opts)?.wait()
    }

    /// Submit many, wait for all (responses in submission order).
    fn infer_many(&self, images: Vec<Packed>) -> anyhow::Result<Vec<InferResponse>> {
        let tickets: Vec<Ticket> = images
            .into_iter()
            .map(|img| self.submit(img))
            .collect::<anyhow::Result<_>>()?;
        // resolve every ticket before surfacing the first error: a
        // mid-list backend drop is the engine's `rejected` count, and
        // short-circuiting would leave later tickets to be miscounted as
        // client cancellations
        let waited: Vec<anyhow::Result<InferResponse>> =
            tickets.into_iter().map(Ticket::wait).collect();
        waited.into_iter().collect()
    }
}

impl InferService for server::Coordinator {
    fn submit_with(&self, image: Packed, opts: InferOptions) -> anyhow::Result<Ticket> {
        server::Coordinator::submit_with(self, image, opts)
    }
}

impl InferService for pool::WorkerPool {
    fn submit_with(&self, image: Packed, opts: InferOptions) -> anyhow::Result<Ticket> {
        pool::WorkerPool::submit_with(self, image, opts)
    }
}

impl InferService for Engine {
    fn submit_with(&self, image: Packed, opts: InferOptions) -> anyhow::Result<Ticket> {
        Engine::submit_with(self, image, opts)
    }
}
