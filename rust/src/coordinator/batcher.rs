//! Dynamic batching: accumulate queued requests until either the batch is
//! full or the oldest request has waited `max_wait` (the classic
//! latency/throughput knob).
//!
//! The drain policy itself is pure and synchronous ([`drain_batch`]) so its
//! invariants are property-testable without threads; the worker loop in
//! `server.rs` wires it to a channel.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::InferRequest;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Hard cap per executed batch (≤ backend max_batch).
    pub max_batch: usize,
    /// Deadline: a request never waits in the queue longer than this
    /// before a (possibly partial) batch is launched.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
        }
    }
}

impl BatcherConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be ≥ 1");
        Ok(())
    }
}

/// Decision produced by [`drain_batch`].
#[derive(Debug, PartialEq, Eq)]
pub enum DrainDecision {
    /// Launch these requests now (FIFO prefix of the queue).
    Launch(usize),
    /// Wait up to this long for more work before re-evaluating.
    Wait(Duration),
    /// Queue empty.
    Idle,
}

/// Pure batching decision over the queue state at time `now`.
///
/// Invariants (property-tested below):
/// * never launches more than `max_batch`;
/// * launches a full batch immediately;
/// * launches a partial batch iff the oldest request has aged out;
/// * otherwise returns the exact remaining wait for the oldest request.
pub fn decide(
    queue_len: usize,
    oldest_enqueued_at: Option<Instant>,
    cfg: &BatcherConfig,
    now: Instant,
) -> DrainDecision {
    if queue_len == 0 {
        return DrainDecision::Idle;
    }
    if queue_len >= cfg.max_batch {
        return DrainDecision::Launch(cfg.max_batch);
    }
    let oldest = oldest_enqueued_at.expect("non-empty queue has an oldest entry");
    let age = now.saturating_duration_since(oldest);
    if age >= cfg.max_wait {
        DrainDecision::Launch(queue_len)
    } else {
        DrainDecision::Wait(cfg.max_wait - age)
    }
}

/// Convenience over a request queue.
pub fn drain_batch(
    queue: &VecDeque<InferRequest>,
    cfg: &BatcherConfig,
    now: Instant,
) -> DrainDecision {
    decide(queue.len(), queue.front().map(|r| r.enqueued_at), cfg, now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::{pack_bits_u64, Packed};
    use crate::util::prng::Xoshiro256;

    fn req(id: u64, enqueued_at: Instant) -> InferRequest {
        InferRequest {
            id,
            image: Packed {
                words: pack_bits_u64(&[0u8; 16]),
                n_bits: 16,
            },
            opts: crate::coordinator::InferOptions::default(),
            enqueued_at,
        }
    }

    fn cfg(max_batch: usize, max_wait_us: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
        }
    }

    #[test]
    fn empty_queue_is_idle() {
        let q = VecDeque::new();
        assert_eq!(drain_batch(&q, &cfg(8, 100), Instant::now()), DrainDecision::Idle);
    }

    #[test]
    fn full_batch_launches_immediately() {
        let now = Instant::now();
        let q: VecDeque<_> = (0..8).map(|i| req(i, now)).collect();
        assert_eq!(drain_batch(&q, &cfg(8, 1_000_000), now), DrainDecision::Launch(8));
        // over-full queue still capped at max_batch
        let q: VecDeque<_> = (0..20).map(|i| req(i, now)).collect();
        assert_eq!(drain_batch(&q, &cfg(8, 1_000_000), now), DrainDecision::Launch(8));
    }

    #[test]
    fn partial_batch_waits_then_ages_out() {
        let t0 = Instant::now();
        let q: VecDeque<_> = (0..3).map(|i| req(i, t0)).collect();
        let c = cfg(8, 100);
        match drain_batch(&q, &c, t0) {
            DrainDecision::Wait(d) => assert!(d <= Duration::from_micros(100)),
            other => panic!("expected Wait, got {other:?}"),
        }
        // after the deadline the partial batch launches
        let later = t0 + Duration::from_micros(150);
        assert_eq!(drain_batch(&q, &c, later), DrainDecision::Launch(3));
    }

    #[test]
    fn wait_is_remaining_time_for_oldest() {
        let t0 = Instant::now();
        let q: VecDeque<_> = vec![req(0, t0)].into();
        let c = cfg(8, 1000);
        let now = t0 + Duration::from_micros(400);
        match drain_batch(&q, &c, now) {
            DrainDecision::Wait(d) => {
                assert!((d.as_micros() as i64 - 600).abs() <= 1, "{d:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zero_max_wait_launches_any_nonempty_queue_immediately() {
        // max_wait == 0: batching is disabled — every non-empty queue
        // launches at once (capped at max_batch), freshness of the oldest
        // request notwithstanding.
        use crate::util::proptest_lite::{gens, Runner};
        Runner::new("decide-max-wait-zero").cases(64).run(
            &gens::Pair(gens::U64(0..=40), gens::U64(1..=16)),
            |(qlen, max_batch)| {
                let (qlen, max_batch) = (*qlen as usize, *max_batch as usize);
                let now = Instant::now();
                let c = cfg(max_batch, 0);
                match decide(qlen, (qlen > 0).then_some(now), &c, now) {
                    DrainDecision::Launch(n) => qlen > 0 && n == qlen.min(max_batch),
                    DrainDecision::Idle => qlen == 0,
                    DrainDecision::Wait(_) => false, // must never wait at max_wait == 0
                }
            },
        );
    }

    #[test]
    fn queue_exactly_max_batch_launches_full_regardless_of_age() {
        // queue length exactly max_batch: a full batch launches even if
        // the oldest request arrived this very instant and max_wait is
        // enormous.
        use crate::util::proptest_lite::{gens, Runner};
        Runner::new("decide-exact-full-batch").cases(64).run(
            &gens::U64(1..=64),
            |&max_batch| {
                let max_batch = max_batch as usize;
                let now = Instant::now();
                let c = cfg(max_batch, 1_000_000_000);
                decide(max_batch, Some(now), &c, now) == DrainDecision::Launch(max_batch)
            },
        );
    }

    #[test]
    fn aged_out_partial_batch_launches_whole_queue() {
        // A partial batch whose oldest entry has aged ≥ max_wait launches
        // with exactly the queue length — the deadline flushes everything
        // queued, never a sub-prefix.  Exactly at the boundary counts as
        // aged (age >= max_wait, not >).
        use crate::util::proptest_lite::{gens, Runner};
        Runner::new("decide-aged-partial").cases(64).run(
            &gens::Pair(gens::Pair(gens::U64(1..=15), gens::U64(1..=500)), gens::U64(0..=500)),
            |((qlen, wait_us), extra_us)| {
                let qlen = *qlen as usize;
                let max_batch = 16; // strictly larger than any qlen here
                let c = cfg(max_batch, *wait_us);
                let t0 = Instant::now();
                let oldest_age = c.max_wait + Duration::from_micros(*extra_us);
                let oldest = t0.checked_sub(oldest_age).unwrap_or(t0);
                // guard against platforms where Instant cannot go back far
                // enough: recompute the age decide() will actually see
                let seen_age = t0.saturating_duration_since(oldest);
                match decide(qlen, Some(oldest), &c, t0) {
                    DrainDecision::Launch(n) => seen_age >= c.max_wait && n == qlen,
                    DrainDecision::Wait(d) => {
                        seen_age < c.max_wait && d == c.max_wait - seen_age
                    }
                    DrainDecision::Idle => false,
                }
            },
        );
    }

    #[test]
    fn boundary_age_exactly_max_wait_launches() {
        // the precise >= boundary, deterministic (no clock arithmetic slop)
        let t0 = Instant::now();
        let c = cfg(8, 100);
        let now = t0 + Duration::from_micros(100); // age == max_wait exactly
        assert_eq!(decide(3, Some(t0), &c, now), DrainDecision::Launch(3));
        // one tick earlier it still waits, for exactly the remainder
        let almost = t0 + Duration::from_micros(99);
        assert_eq!(
            decide(3, Some(t0), &c, almost),
            DrainDecision::Wait(Duration::from_micros(1))
        );
    }

    #[test]
    fn property_never_exceeds_max_batch_and_launch_is_prefix() {
        // randomized queue states: the decision must never launch more than
        // max_batch, never launch 0, and Launch(n) must imply n ≤ queue.len()
        let mut rng = Xoshiro256::new(2025);
        for case in 0..500 {
            let t0 = Instant::now();
            let max_batch = 1 + rng.below(16) as usize;
            let max_wait_us = rng.below(500);
            let qlen = rng.below(40) as usize;
            let q: VecDeque<_> = (0..qlen)
                .map(|i| {
                    let age = Duration::from_micros(rng.below(1000));
                    req(i as u64, t0.checked_sub(age).unwrap_or(t0))
                })
                .collect();
            let c = cfg(max_batch, max_wait_us);
            match drain_batch(&q, &c, t0) {
                DrainDecision::Launch(n) => {
                    assert!(n >= 1 && n <= max_batch && n <= q.len(), "case {case}");
                    // launch must be justified: full batch or aged oldest
                    let oldest_age = t0.saturating_duration_since(q.front().unwrap().enqueued_at);
                    assert!(
                        q.len() >= max_batch || oldest_age >= c.max_wait,
                        "case {case}: unjustified launch"
                    );
                }
                DrainDecision::Wait(d) => {
                    assert!(!q.is_empty() && d <= c.max_wait, "case {case}");
                }
                DrainDecision::Idle => assert!(q.is_empty(), "case {case}"),
            }
        }
    }
}
