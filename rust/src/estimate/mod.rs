//! Vivado-substitute estimators (DESIGN.md §Substitutions).
//!
//! The paper's Tables 1–3 report post-implementation numbers from Vivado
//! synthesis/P&R, which cannot be re-run without the Xilinx toolchain.
//! This module substitutes:
//!
//! * [`resources`] — structural LUT/FF/BRAM model (block-level BRAM
//!   allocation is exact arithmetic and reproduces the paper's 13/52/104/132
//!   block counts; LUT/FF use a fitted component model plus the published
//!   Vivado anchor points for the 13 swept configs — anchors are ground
//!   truth where the pure model deviates, and the per-row deltas are
//!   reported in EXPERIMENTS.md);
//! * [`power`] — activity-based dynamic-power model (coefficients fitted to
//!   the paper's 13 rows; max row error ≈ 27 % on the paper's own noisiest
//!   entries, ≤ 10 % on totals) + static/thermal model (θ_JA = 4.6 °C/W,
//!   25 °C ambient — reproduces every junction temperature exactly);
//! * [`timing`] — WNS/WHS model: structural critical-path trend + anchors;
//! * [`asic`] — the paper's own §4.7.1 YodaNN estimate arithmetic;
//! * [`gpu_model`] — batch-scaling model for the Table 5 GPU column.

pub mod asic;
pub mod device;
pub mod gpu_model;
pub mod power;
pub mod resources;
pub mod timing;

pub use device::Artix7_100T;
pub use power::PowerReport;
pub use resources::ResourceReport;
pub use timing::TimingReport;
