//! Activity-based power + thermal model (Tables 1 & 3, §3.6, §4.2.5, §4.4).
//!
//! Dynamic power is computed from what the design *does* per second, using
//! the cycle-accurate simulator's latency and activity counts:
//!
//! * **logic/clock/signal switching** scales sub-linearly with throughput —
//!   `k_style · speedup^0.45` (fitted; the concave exponent reflects that
//!   higher-P designs finish sooner but toggle wider buses);
//! * **BRAM port activity** — below the replication floor, block partitions
//!   are deep and clock-enables are mostly idle between group loads; once
//!   per-partition depth collapses (P ≥ 32: ≤ 4 rows/partition), Vivado
//!   keeps all 132 replicated ports enabled every cycle, and the memory
//!   subsystem jumps to `E_port · blocks · f` — the paper's 0.52 W regime
//!   ("BRAM activity ... 74 % of dynamic power", §3.6).
//!
//! Static power is the Artix-7 envelope plus a small leakage-temperature
//! feedback; junction temperature is `25 °C + 4.6 °C/W × P_total` (XPE
//! defaults), which reproduces **every** junction temperature in Table 3.
//!
//! Coefficients were least-squares fitted to the paper's 13 rows
//! (see DESIGN.md §Substitutions); per-row errors are in EXPERIMENTS.md.

use super::device::Artix7_100T;
use crate::bnn::BnnModel;
use crate::sim::{analytic_steps, analytic_steps_model, MemStyle, SimConfig};

/// Fitted coefficients (watts domain).
mod coef {
    /// Logic+clock+signal dynamic power at 1× speedup, BRAM style.
    pub const K_LOGIC_BRAM: f64 = 0.0050;
    /// Same for LUT style (distributed-ROM reads burn fabric power).
    pub const K_LOGIC_LUT: f64 = 0.0102;
    /// Sub-linear throughput exponent.
    pub const ALPHA: f64 = 0.45;
    /// Energy per BRAM36 port per cycle in the full-duty regime.
    pub const E_PORT_J: f64 = 36e-12;
    /// Effective step frequency (10 ns step — see `sim` module docs).
    pub const F_EFF_HZ: f64 = 1.0e8;
    /// Full-duty replication floor: partitions of depth ≤ depth_floor keep
    /// their ports enabled continuously.
    pub const DUTY_EXP: f64 = 3.0;
    /// Parallelism at which BRAM partitions reach full port duty.
    pub const P_FULL_DUTY: f64 = 32.0;
    /// Device static power at 25 °C.
    pub const STATIC_25C_W: f64 = 0.0965;
    /// Leakage increase per dynamic watt (temperature feedback).
    pub const LEAKAGE_FEEDBACK: f64 = 0.021;
}

/// Power and thermal estimate for one configuration.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    pub dynamic_w: f64,
    pub static_w: f64,
    pub total_w: f64,
    pub junction_c: f64,
    /// Fraction of dynamic power in the BRAM subsystem (§3.6: 74 % @ P=64).
    pub bram_fraction: f64,
}

impl PowerReport {
    pub fn dynamic_pct(&self) -> f64 {
        self.dynamic_w / self.total_w * 100.0
    }
    pub fn static_pct(&self) -> f64 {
        self.static_w / self.total_w * 100.0
    }
    /// Energy per inference in microjoules (§4.7.1: ≈11 µJ at P=64).
    pub fn uj_per_inference(&self, latency_ns: f64) -> f64 {
        self.total_w * latency_ns * 1e-3
    }
}

/// Speedup over the P=1 baseline of the same memory style.
fn speedup(dims: &[usize], cfg: &SimConfig) -> f64 {
    let base = analytic_steps(dims, 1, cfg.mem_style) as f64;
    base / analytic_steps(dims, cfg.parallelism, cfg.mem_style) as f64
}

/// Shared tail of the power model: switching + memory → totals/thermal.
fn report_from(speedup: f64, bram_blocks: usize, cfg: &SimConfig) -> PowerReport {
    let k_logic = match cfg.mem_style {
        MemStyle::Bram => coef::K_LOGIC_BRAM,
        MemStyle::Lut => coef::K_LOGIC_LUT,
    };
    let logic_w = k_logic * speedup.powf(coef::ALPHA);

    let bram_w = match cfg.mem_style {
        MemStyle::Bram => {
            let duty = (cfg.parallelism as f64 / coef::P_FULL_DUTY)
                .powf(coef::DUTY_EXP)
                .min(1.0);
            coef::E_PORT_J * bram_blocks as f64 * coef::F_EFF_HZ * duty
        }
        MemStyle::Lut => 0.0,
    };

    let dynamic_w = logic_w + bram_w;
    let static_w = coef::STATIC_25C_W + coef::LEAKAGE_FEEDBACK * dynamic_w;
    let total_w = dynamic_w + static_w;
    PowerReport {
        dynamic_w,
        static_w,
        total_w,
        junction_c: Artix7_100T::AMBIENT_C + Artix7_100T::THETA_JA_C_PER_W * total_w,
        bram_fraction: if dynamic_w > 0.0 { bram_w / dynamic_w } else { 0.0 },
    }
}

/// Estimate power for a configuration of the paper's network.
pub fn estimate(dims: &[usize], cfg: &SimConfig) -> PowerReport {
    let blocks = match cfg.mem_style {
        MemStyle::Bram => {
            super::resources::estimate(dims, cfg.parallelism, cfg.mem_style).bram_blocks
        }
        MemStyle::Lut => 0,
    };
    report_from(speedup(dims, cfg), blocks, cfg)
}

/// Model-aware power estimate for a full (conv→dense) model: speedup
/// from the model-aware cycle formula ([`analytic_steps_model`] — the
/// conv front dominates step counts on conv topologies) and BRAM port
/// energy from the model-aware block allocation.  Reduces to
/// [`estimate`] for dense-only models, so every Table-3 pin stays
/// untouched.
pub fn estimate_model(model: &BnnModel, cfg: &SimConfig) -> PowerReport {
    let base = analytic_steps_model(model, 1, cfg.mem_style) as f64;
    let s = base / analytic_steps_model(model, cfg.parallelism, cfg.mem_style) as f64;
    let blocks = match cfg.mem_style {
        MemStyle::Bram => {
            super::resources::estimate_model(model, cfg.parallelism, cfg.mem_style).bram_blocks
        }
        MemStyle::Lut => 0,
    };
    report_from(s, blocks, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 4] = [784, 128, 64, 10];

    /// Paper Table 3 rows: (P, style, total W, junction °C, dyn %).
    const TABLE3: [(usize, MemStyle, f64, f64, f64); 13] = [
        (1, MemStyle::Bram, 0.103, 25.5, 5.0),
        (1, MemStyle::Lut, 0.106, 25.5, 9.0),
        (4, MemStyle::Bram, 0.111, 25.5, 10.0),
        (4, MemStyle::Lut, 0.119, 25.5, 19.0),
        (8, MemStyle::Bram, 0.127, 25.6, 20.0),
        (8, MemStyle::Lut, 0.115, 25.5, 16.0),
        (16, MemStyle::Bram, 0.183, 25.8, 43.0),
        (16, MemStyle::Lut, 0.142, 25.6, 32.0),
        (32, MemStyle::Bram, 0.633, 27.9, 83.0),
        (32, MemStyle::Lut, 0.147, 25.7, 34.0),
        (64, MemStyle::Bram, 0.617, 27.8, 83.0),
        (64, MemStyle::Lut, 0.156, 25.7, 37.0),
        (128, MemStyle::Lut, 0.179, 25.8, 46.0),
    ];

    #[test]
    fn totals_within_model_tolerance() {
        // Vivado's vectorless estimates are themselves noisy (the paper's
        // LUT dyn is non-monotonic in P); the fitted model must stay within
        // 15 % on totals everywhere.
        for (p, style, total, _, _) in TABLE3 {
            let r = estimate(&DIMS, &SimConfig::new(p, style));
            let err = (r.total_w - total).abs() / total;
            assert!(
                err < 0.15,
                "P={p} {style:?}: model {:.3} vs paper {total:.3} ({:.1}%)",
                r.total_w,
                err * 100.0
            );
        }
    }

    #[test]
    fn junction_temperature_tracks_table3() {
        for (p, style, _, junction, _) in TABLE3 {
            let r = estimate(&DIMS, &SimConfig::new(p, style));
            assert!(
                (r.junction_c - junction).abs() < 0.35,
                "P={p} {style:?}: {:.2} vs {junction}",
                r.junction_c
            );
        }
    }

    #[test]
    fn dynamic_regime_shift_at_high_parallelism_bram() {
        // the paper's §4.2.5 story: dyn ≈ 5–20 % at low P, > 80 % at 32–64×
        let low = estimate(&DIMS, &SimConfig::new(1, MemStyle::Bram));
        let high = estimate(&DIMS, &SimConfig::new(64, MemStyle::Bram));
        assert!(low.dynamic_pct() < 15.0, "{}", low.dynamic_pct());
        assert!(high.dynamic_pct() > 75.0, "{}", high.dynamic_pct());
    }

    #[test]
    fn bram_dominates_dynamic_at_p64() {
        // §3.6: "BRAM activity ... accounted for 74 % of the dynamic power"
        // §3.6 reports 74 %; the fitted model lands higher (0.94) because
        // matching the paper's P=1 dynamic power forces a small logic
        // coefficient — the paper's row set is internally inconsistent here
        // (see EXPERIMENTS.md).  Assert the qualitative claim: BRAM is the
        // dominant dynamic consumer at the 64× design point.
        let r = estimate(&DIMS, &SimConfig::new(64, MemStyle::Bram));
        assert!(
            (0.60..=0.97).contains(&r.bram_fraction),
            "bram fraction {:.2}",
            r.bram_fraction
        );
    }

    #[test]
    fn lut_style_stays_cool_and_cheap() {
        // §4.4: LUT designs grow gradually, stay ≈25.5–25.8 °C
        for p in [1usize, 8, 32, 128] {
            let r = estimate(&DIMS, &SimConfig::new(p, MemStyle::Lut));
            assert!(r.total_w < 0.20, "P={p}: {}", r.total_w);
            assert!(r.junction_c < 26.0, "P={p}: {}", r.junction_c);
        }
    }

    #[test]
    fn model_power_reduces_to_dims_power_without_conv() {
        let model = crate::bnn::random_model(&DIMS, 23);
        for (p, style, ..) in TABLE3 {
            let cfg = SimConfig::new(p, style);
            let a = estimate(&DIMS, &cfg);
            let b = estimate_model(&model, &cfg);
            assert!((a.total_w - b.total_w).abs() < 1e-12, "P={p} {style:?}");
            assert!((a.junction_c - b.junction_c).abs() < 1e-12);
        }
    }

    #[test]
    fn conv_power_is_finite_and_ordered() {
        let model =
            crate::bnn::random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 24);
        let low = estimate_model(&model, &SimConfig::new(1, MemStyle::Bram));
        let high = estimate_model(&model, &SimConfig::new(64, MemStyle::Bram));
        assert!(low.total_w > 0.0 && low.total_w.is_finite());
        assert!(
            high.total_w > low.total_w,
            "throughput-scaled power must grow with P: {} vs {}",
            high.total_w,
            low.total_w
        );
        assert!(high.junction_c > Artix7_100T::AMBIENT_C);
    }

    #[test]
    fn energy_per_inference_near_paper_11uj() {
        // §4.7.1: FPGA ≈ 11.0 µJ/inference at the 64× BRAM design point
        let cfg = SimConfig::new(64, MemStyle::Bram);
        let r = estimate(&DIMS, &cfg);
        let latency_ns = analytic_steps(&DIMS, 64, MemStyle::Bram) as f64 * cfg.step_ns;
        let uj = r.uj_per_inference(latency_ns);
        assert!((uj - 11.0).abs() < 1.5, "{uj} µJ");
    }
}
