//! GPU batch-latency model for the Table 5 comparison.
//!
//! No GPU exists in this environment (DESIGN.md §Substitutions), so the
//! Tesla-T4 column is modeled with the standard two-parameter accelerator
//! law the paper's own measurements follow:
//!
//! ```text
//!   t(B) = t_launch + B · t_image_saturated
//! ```
//!
//! Calibrated to the paper's Table 5 (t_launch = 0.82 ms kernel-launch +
//! transfer overhead; t_image = 76 ns/image at Tensor-Core saturation), it
//! reproduces the table's shape: flat latency through B = 1000, per-image
//! cost collapsing to sub-µs at B = 10⁴ — the crossover the section's
//! narrative is built on.

/// Modeled NVIDIA T4 parameters (calibrated to Table 5).
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Fixed per-call overhead (launch + transfer), ms.
    pub launch_ms: f64,
    /// Saturated per-image time, ms.
    pub per_image_ms: f64,
    /// Run-to-run jitter fraction (the paper's std-dev column).
    pub jitter_frac: f64,
    /// Board TDP, watts (§4.7.2: 70 W).
    pub tdp_w: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self {
            launch_ms: 0.82,
            per_image_ms: 7.6e-5, // 76 ns
            jitter_frac: 0.08,
            tdp_w: 70.0,
        }
    }
}

impl GpuModel {
    /// Mean batch latency in ms.
    pub fn batch_latency_ms(&self, batch: usize) -> f64 {
        self.launch_ms + batch as f64 * self.per_image_ms
    }

    /// Per-image latency in ms.
    pub fn per_image_latency_ms(&self, batch: usize) -> f64 {
        self.batch_latency_ms(batch) / batch as f64
    }

    /// Deterministic pseudo-measurement series (mean + seeded jitter), used
    /// by the Table 5 bench to produce a std-dev column like the paper's.
    pub fn sample_series(&self, batch: usize, runs: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::util::prng::Xoshiro256::new(seed ^ batch as u64);
        let mean = self.batch_latency_ms(batch);
        (0..runs)
            .map(|_| (mean * (1.0 + self.jitter_frac * rng.normal())).max(mean * 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table5_gpu_column_shape() {
        let m = GpuModel::default();
        // paper: (batch, mean ms) — model within 35 % (the paper's own
        // B=100 row is a 50 % outlier vs its neighbours)
        for (batch, paper_ms, tol) in [
            (1usize, 0.82, 0.05),
            (10, 0.87, 0.10),
            (1000, 0.86, 0.10),
            (10000, 1.58, 0.05),
        ] {
            let got = m.batch_latency_ms(batch);
            assert!(
                (got - paper_ms).abs() / paper_ms < tol,
                "B={batch}: {got} vs {paper_ms}"
            );
        }
    }

    #[test]
    fn per_image_collapses_at_large_batch() {
        let m = GpuModel::default();
        // paper: 0.82 ms at B=1 → 0.16 µs at B=10⁴
        assert!(m.per_image_latency_ms(1) > 0.8);
        let per_10k = m.per_image_latency_ms(10_000);
        assert!((per_10k - 0.00016).abs() < 0.00003, "{per_10k}");
    }

    #[test]
    fn sample_series_statistics() {
        let m = GpuModel::default();
        let s = m.sample_series(1000, 200, 7);
        assert_eq!(s.len(), 200);
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        assert!((mean - m.batch_latency_ms(1000)).abs() / mean < 0.05);
        assert!(s.iter().all(|&x| x > 0.0));
    }
}
