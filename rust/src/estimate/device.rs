//! Target-device resource envelopes.

/// Xilinx Artix-7 XC7A100T (Nexys A7-100T board) — the paper's target.
#[allow(non_camel_case_types)]
pub struct Artix7_100T;

impl Artix7_100T {
    pub const LUTS: usize = 63_400;
    pub const FLIP_FLOPS: usize = 126_800;
    /// RAMB36E1 blocks on the device.
    pub const BRAM36: usize = 135;
    /// Blocks actually placeable by the design before routing fails —
    /// the paper saturates at 132/135 = 97.78 % (§3.6).
    pub const BRAM36_USABLE: usize = 132;
    pub const DSP48: usize = 240;
    pub const IO: usize = 210;
    /// XPE defaults the paper's thermal numbers are consistent with.
    pub const AMBIENT_C: f64 = 25.0;
    pub const THETA_JA_C_PER_W: f64 = 4.6;
}

/// Percent-of-device helpers used across reports.
pub fn pct(used: usize, total: usize) -> f64 {
    used as f64 / total as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bram_saturation_is_papers_97_78() {
        let p = pct(Artix7_100T::BRAM36_USABLE, Artix7_100T::BRAM36);
        assert!((p - 97.78).abs() < 0.01, "{p}");
    }

    #[test]
    fn paper_p1_bram_pct() {
        assert!((pct(13, Artix7_100T::BRAM36) - 9.63).abs() < 0.01);
    }
}
