//! ASIC comparison (§4.7.1): the paper's own estimate-based YodaNN
//! arithmetic, reproduced as code so the platform-comparison bench can
//! regenerate the section's numbers.

/// Published YodaNN (Andri et al., ISVLSI'16) figures the paper cites.
pub struct YodaNn;

impl YodaNn {
    /// Peak clock at nominal voltage.
    pub const CLOCK_MHZ: f64 = 480.0;
    /// Peak throughput at 1.2 V.
    pub const PEAK_TOPS: f64 = 1.5;
    /// Core power at 0.6 V.
    pub const CORE_POWER_W: f64 = 895e-6;
    /// Sustained throughput used in the paper's estimate.
    pub const SUSTAINED_GOPS: f64 = 20.1;
    /// Energy efficiency used in the paper's estimate.
    pub const EFFICIENCY_TOPS_PER_W: f64 = 59.2;
    /// Latency the paper quotes for a comparable 3-layer binary model.
    pub const LATENCY_MS: f64 = 7.5;
    /// Energy per inference the paper quotes.
    pub const UJ_PER_INFERENCE: f64 = 2.6;
    /// Mass-production unit cost band (USD).
    pub const UNIT_COST_USD: (f64, f64) = (5.0, 10.0);
}

/// The paper's Eq. in §4.7.1: P ≈ sustained-throughput / efficiency.
pub fn yodann_inferred_power_w() -> f64 {
    Yodann_sustained_gops() / (YodaNn::EFFICIENCY_TOPS_PER_W * 1e3)
}

#[allow(non_snake_case)]
fn Yodann_sustained_gops() -> f64 {
    YodaNn::SUSTAINED_GOPS
}

/// Side-by-side platform summary row.
#[derive(Clone, Debug)]
pub struct PlatformRow {
    pub platform: &'static str,
    pub latency_ms: f64,
    pub power_w: f64,
    pub uj_per_inference: f64,
    pub unit_cost_usd: (f64, f64),
    pub reconfigurable: bool,
}

/// Build the §4.7.1 comparison given the FPGA design point's measured
/// latency and modeled power.
pub fn comparison(fpga_latency_ms: f64, fpga_power_w: f64) -> Vec<PlatformRow> {
    vec![
        PlatformRow {
            platform: "FPGA (this work, 64x BRAM)",
            latency_ms: fpga_latency_ms,
            power_w: fpga_power_w,
            uj_per_inference: fpga_power_w * fpga_latency_ms * 1e3,
            unit_cost_usd: (150.0, 150.0),
            reconfigurable: true,
        },
        PlatformRow {
            platform: "ASIC (YodaNN, estimated)",
            latency_ms: YodaNn::LATENCY_MS,
            power_w: yodann_inferred_power_w(),
            uj_per_inference: YodaNn::UJ_PER_INFERENCE,
            unit_cost_usd: YodaNn::UNIT_COST_USD,
            reconfigurable: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inferred_power_matches_paper() {
        // §4.7.1: P_ASIC ≈ 20.1 GOp/s ÷ 59.2 TOp/s/W = 0.00034 W
        let p = yodann_inferred_power_w();
        assert!((p - 0.00034).abs() < 0.00002, "{p}");
    }

    #[test]
    fn fpga_vs_asic_shape() {
        // the paper's qualitative result: FPGA is ~400× faster per image,
        // ASIC is ~4× more energy-efficient per inference
        let rows = comparison(0.0178, 0.617);
        let fpga = &rows[0];
        let asic = &rows[1];
        assert!(asic.latency_ms / fpga.latency_ms > 300.0);
        assert!(fpga.uj_per_inference > 2.0 * asic.uj_per_inference);
        assert!((fpga.uj_per_inference - 11.0).abs() < 1.0, "{}", fpga.uj_per_inference);
        assert!(fpga.reconfigurable && !asic.reconfigurable);
    }
}
