//! Timing-slack model (Table 2, §4.3): WNS / WHS after place-and-route.
//!
//! WNS is modeled as the 12.5 ns clock period minus a structural
//! critical-path estimate: base FSM decode + the popcount-accumulator
//! compare path + a routing-pressure term that grows with occupied logic
//! and (for BRAM style) block fan-out.  P&R noise makes the paper's own
//! numbers non-monotonic (§4.3 calls out the 16× BRAM dip and the 128×
//! recovery), so exact reproduction is out of scope for a forward model —
//! the anchors carry the published values and the model supplies unseen
//! configurations.  All modeled configurations meet timing (WNS > 0), the
//! paper's headline claim.

use crate::sim::MemStyle;

/// Post-P&R slack estimate for one configuration.
#[derive(Clone, Copy, Debug)]
pub struct TimingReport {
    /// Worst negative slack (ns); positive ⇒ setup timing met.
    pub wns_ns: f64,
    /// Worst hold slack (ns); positive ⇒ no hold violations.
    pub whs_ns: f64,
    pub meets_80mhz: bool,
}

/// The paper's clock period target (§3.5: 80 MHz).
pub const CLOCK_PERIOD_NS: f64 = 12.5;

/// Structural forward model.
pub fn estimate(parallelism: usize, style: MemStyle) -> TimingReport {
    let p = parallelism as f64;
    // logic depth: FSM decode (~3.2 ns) + 11-bit add/compare (~4.1 ns)
    let base_path = 7.3;
    // routing pressure: grows with active units and memory fan-out
    let routing = match style {
        MemStyle::Bram => 0.42 * p.log2().max(0.0) + 0.9,
        MemStyle::Lut => 0.30 * p.log2().max(0.0) + 0.55,
    };
    let wns = CLOCK_PERIOD_NS - base_path - routing;
    // hold slack: small positive margin, shrinking slightly with fan-out
    let whs = (0.19 - 0.016 * p.log2().max(0.0)).max(0.02);
    TimingReport {
        wns_ns: wns,
        whs_ns: whs,
        meets_80mhz: wns > 0.0 && whs > 0.0,
    }
}

/// Extra critical-path contribution of the conv front's window mux: the
/// broadcast input bit goes through one receptive-field mux level
/// (stride/pad address decode is registered, so only the final mux is on
/// the compute path).
pub const CONV_WINDOW_MUX_NS: f64 = 0.35;

/// Model-aware structural estimate: the dense path plus one window-mux
/// level when the model carries a conv front.  Reduces to [`estimate`]
/// for dense-only models; every modeled conv configuration must still
/// meet 80 MHz (worst case `7.3 + 3.84 + 0.35 = 11.49 ns < 12.5 ns`).
pub fn estimate_model(
    model: &crate::bnn::BnnModel,
    parallelism: usize,
    style: MemStyle,
) -> TimingReport {
    let mut t = estimate(parallelism, style);
    if !model.conv.is_empty() {
        t.wns_ns -= CONV_WINDOW_MUX_NS;
        t.meets_80mhz = t.wns_ns > 0.0 && t.whs_ns > 0.0;
    }
    t
}

/// Published Table 2 values.
pub fn vivado_anchor(parallelism: usize, style: MemStyle) -> Option<TimingReport> {
    let (wns, whs) = match (parallelism, style) {
        (1, MemStyle::Bram) => (1.144, 0.169),
        (1, MemStyle::Lut) => (3.564, 0.115),
        (4, MemStyle::Bram) => (1.525, 0.132),
        (4, MemStyle::Lut) => (1.975, 0.039),
        (8, MemStyle::Bram) => (1.043, 0.062),
        (8, MemStyle::Lut) => (1.708, 0.187),
        (16, MemStyle::Bram) => (0.370, 0.033),
        (16, MemStyle::Lut) => (1.109, 0.050),
        (32, MemStyle::Bram) => (0.680, 0.075),
        (32, MemStyle::Lut) => (1.950, 0.129),
        (64, MemStyle::Bram) => (0.939, 0.081),
        (64, MemStyle::Lut) => (0.519, 0.040),
        (128, MemStyle::Lut) => (1.163, 0.025),
        _ => return None,
    };
    Some(TimingReport {
        wns_ns: wns,
        whs_ns: whs,
        meets_80mhz: true,
    })
}

/// Anchored-when-known, modeled otherwise.
pub fn best(parallelism: usize, style: MemStyle) -> TimingReport {
    vivado_anchor(parallelism, style).unwrap_or_else(|| estimate(parallelism, style))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_configuration_meets_timing() {
        // §4.3: "Overall all configurations meet the 80 MHz timing target."
        for p in [1usize, 2, 4, 8, 16, 32, 64, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                let t = estimate(p, style);
                assert!(t.meets_80mhz, "P={p} {style:?}: WNS {}", t.wns_ns);
                assert!(t.wns_ns > 0.0 && t.whs_ns > 0.0);
            }
        }
    }

    #[test]
    fn wns_decreases_with_parallelism_in_model() {
        let a = estimate(1, MemStyle::Bram).wns_ns;
        let b = estimate(64, MemStyle::Bram).wns_ns;
        assert!(b < a, "routing pressure must reduce slack: {a} → {b}");
    }

    #[test]
    fn anchors_match_table2() {
        let t = vivado_anchor(16, MemStyle::Bram).unwrap();
        assert!((t.wns_ns - 0.370).abs() < 1e-9);
        assert!((t.whs_ns - 0.033).abs() < 1e-9);
        assert!(vivado_anchor(128, MemStyle::Bram).is_none());
        // all 13 rows positive
        for p in [1usize, 4, 8, 16, 32, 64, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                if let Some(t) = vivado_anchor(p, style) {
                    assert!(t.wns_ns > 0.0 && t.whs_ns > 0.0);
                }
            }
        }
    }

    #[test]
    fn conv_models_still_meet_timing() {
        let conv = crate::bnn::random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 9);
        let dense = crate::bnn::random_model(&[784, 128, 64, 10], 9);
        for p in [1usize, 16, 64, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                let tc = estimate_model(&conv, p, style);
                let td = estimate_model(&dense, p, style);
                // dense-only reduces to the dims-based model exactly
                assert_eq!(td.wns_ns, estimate(p, style).wns_ns);
                // the window mux costs slack but never breaks 80 MHz
                assert!((tc.wns_ns - (td.wns_ns - CONV_WINDOW_MUX_NS)).abs() < 1e-12);
                assert!(tc.meets_80mhz, "P={p} {style:?}: WNS {}", tc.wns_ns);
            }
        }
    }

    #[test]
    fn hold_slack_small_positive_band() {
        // §4.3: WHS ranges 0.025–0.187 ns across configurations
        for p in [1usize, 8, 64, 128] {
            let t = estimate(p, MemStyle::Lut);
            assert!((0.02..0.25).contains(&t.whs_ns), "P={p}: {}", t.whs_ns);
        }
    }
}
