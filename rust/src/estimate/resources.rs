//! LUT / FF / BRAM utilization model (Table 1 columns 4–6, §3.6, §4.2.3/4).
//!
//! Three layers of fidelity:
//!
//! 1. **BRAM block allocation** — exact arithmetic.  Weight ROMs are
//!    partitioned per parallel unit; a partition of layer *l* stores
//!    `⌈N_l/P⌉` rows of `I_l` bits and is width-sliced into RAMB36 blocks
//!    (72-bit max SDP width).  The 784- and 128-wide hidden-layer ROMs are
//!    BRAM-mapped, the 640-bit output ROM is LUT-mapped (that reproduces
//!    the paper's 13 blocks/unit: 11 + 2).  Demand `13·P` saturates at the
//!    132 usable blocks — exactly the paper's 9.63/38.52/77.04/97.78 %.
//! 2. **Structural LUT/FF model** — component sums (FSM base, per-unit
//!    datapath, per-block address/control, distributed-ROM bits, routing
//!    replication).  Captures trends; Vivado's logic folding makes some
//!    published rows non-monotonic, which no forward model reproduces.
//! 3. **Vivado anchors** — the paper's published values for its 13 swept
//!    configurations, used by the table-reproduction benches;
//!    EXPERIMENTS.md reports model-vs-anchor deltas per row.

use super::device::{pct, Artix7_100T};
use crate::bnn::BnnModel;
use crate::sim::bram::blocks_for;
use crate::sim::lutrom::luts_for;
use crate::sim::MemStyle;

/// Resource usage of one configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResourceReport {
    pub luts: usize,
    pub flip_flops: usize,
    pub bram_blocks: usize,
    /// true when BRAM demand exceeded the usable cap and weights spilled
    /// to distributed ROM ("automatic LUT fallback", §3.5).
    pub bram_overflow: bool,
    /// true when the configuration fails to synthesize at all (the paper's
    /// BRAM > 64 and LUT > 128 limits, §4.2.1).
    pub synthesizable: bool,
}

impl ResourceReport {
    pub fn lut_pct(&self) -> f64 {
        pct(self.luts, Artix7_100T::LUTS)
    }
    pub fn ff_pct(&self) -> f64 {
        pct(self.flip_flops, Artix7_100T::FLIP_FLOPS)
    }
    pub fn bram_pct(&self) -> f64 {
        pct(self.bram_blocks, Artix7_100T::BRAM36)
    }
}

/// BRAM-36 block demand before capping: per-unit partitions of the
/// BRAM-mapped layers (hidden layers; the small output ROM is LUT-mapped).
pub fn bram_demand(dims: &[usize], parallelism: usize) -> usize {
    let mut blocks = 0;
    let n_layers = dims.len() - 1;
    for (li, w) in dims.windows(2).enumerate() {
        let (n_in, n_out) = (w[0], w[1]);
        if li + 1 == n_layers {
            continue; // output layer → LUT-ROM (640 bits in the paper)
        }
        let depth = n_out.div_ceil(parallelism);
        blocks += parallelism * blocks_for(n_in, depth);
    }
    blocks
}

/// Structural (forward-model) estimate.
pub fn estimate(dims: &[usize], parallelism: usize, style: MemStyle) -> ResourceReport {
    let p = parallelism;
    let n_layers = dims.len() - 1;

    // --- BRAM ---------------------------------------------------------------
    let (bram_blocks, overflow_partitions) = match style {
        MemStyle::Bram => {
            let demand = bram_demand(dims, p);
            if demand <= Artix7_100T::BRAM36_USABLE {
                (demand, 0)
            } else {
                // saturate: all usable blocks consumed (partial partitions
                // included — the paper reports 132/135 at every saturated P)
                let per_unit = demand / p;
                let fitting_units = Artix7_100T::BRAM36_USABLE / per_unit.max(1);
                (Artix7_100T::BRAM36_USABLE, p - fitting_units)
            }
        }
        MemStyle::Lut => (0, p),
    };

    // --- LUTs ----------------------------------------------------------------
    let base_ctrl = 420usize; // FSM, counters, argmax comparator, display
    let unit_logic = 40 * p; // XNOR, popcount accumulator, threshold compare
    let bram_ctrl = 25 * bram_blocks; // address gen, enables, sync per block
    // distributed ROM for: output layer always; spilled/all partitions
    // Partition cost: depth-1 "ROMs" are constants folded into the XNOR
    // wiring (≈ width/16 residual LUTs); deeper partitions cost one LUT6
    // column per output bit per 64 rows.  Vivado additionally packs/shares
    // shallow replicated columns, so this is an upper-bound trend model —
    // the published anchors are ground truth for the swept configs.
    let partition_cost = |n_in: usize, depth: usize| -> usize {
        if depth <= 1 {
            n_in / 16
        } else {
            luts_for(n_in, depth)
        }
    };
    let mut lutrom = 0usize;
    for (li, w) in dims.windows(2).enumerate() {
        let (n_in, n_out) = (w[0], w[1]);
        let depth = n_out.div_ceil(p);
        if li + 1 == n_layers {
            lutrom += p.min(n_out) * partition_cost(n_in, depth);
        } else {
            lutrom += overflow_partitions.min(p) * partition_cost(n_in, depth);
        }
    }
    // thresholds (11-bit LUT-ROMs per hidden layer)
    for w in dims.windows(2).take(n_layers - 1) {
        lutrom += luts_for(11, w[1]);
    }
    // routing/replication overhead grows with parallel fan-out
    let routing = ((p as f64).sqrt() * 110.0) as usize;
    let luts = base_ctrl + unit_logic + bram_ctrl + lutrom + routing;

    // --- FFs -----------------------------------------------------------------
    // popcount counters (11 bit/unit), score+activation regs, FSM state,
    // per-block output registers for BRAM style.
    let ff = 300 + 13 * p + 30 * bram_blocks + dims[1..n_layers].iter().sum::<usize>();

    // --- synthesizability limits (§4.2.1) -------------------------------------
    let synthesizable = match style {
        MemStyle::Bram => p <= 64,
        MemStyle::Lut => p <= 128,
    };

    ResourceReport {
        luts,
        flip_flops: ff,
        bram_blocks,
        bram_overflow: overflow_partitions > 0 && style == MemStyle::Bram,
        synthesizable,
    }
}

/// Per-model dimension vector of the dense stack (`[dense_n_in,
/// n_out…]`) — what the dims-based estimators consume.
fn dense_dims(model: &BnnModel) -> Vec<usize> {
    let mut dims = vec![model.dense_n_in()];
    dims.extend(model.layers.iter().map(|l| l.n_out));
    dims
}

/// BRAM-36 demand for a full (conv→dense) model before capping: the
/// dense demand plus the conv cores — each conv layer is a per-unit
/// partitioned ROM of `⌈C_out/P⌉` rows × `k²·C_in` bits, exactly like a
/// hidden dense layer with the patch width as its row width.  Reduces to
/// [`bram_demand`] for dense-only models.
pub fn bram_demand_model(model: &BnnModel, parallelism: usize) -> usize {
    let mut blocks = bram_demand(&dense_dims(model), parallelism);
    for cl in &model.conv {
        let depth = cl.out_ch().div_ceil(parallelism);
        blocks += parallelism * blocks_for(cl.patch_bits(), depth);
    }
    blocks
}

/// Structural estimate for a full (conv→dense) model: the dense-stack
/// estimate plus the conv datapath adders — conv weight ROMs (BRAM
/// blocks under the usable cap, distributed ROM on spill or LUT style),
/// per-channel 11-bit threshold ROMs, and the window mux + stride/pad
/// address generator that gathers each receptive field onto the
/// broadcast line.  Reduces to [`estimate`] for dense-only models, so
/// every Table-1 pin stays untouched.
pub fn estimate_model(model: &BnnModel, parallelism: usize, style: MemStyle) -> ResourceReport {
    let p = parallelism;
    let mut r = estimate(&dense_dims(model), p, style);
    for cl in &model.conv {
        let (patch_bits, out_ch) = (cl.patch_bits(), cl.out_ch());
        let depth = out_ch.div_ceil(p);
        match style {
            MemStyle::Bram => {
                let demand = p * blocks_for(patch_bits, depth);
                let free = Artix7_100T::BRAM36_USABLE.saturating_sub(r.bram_blocks);
                let granted = demand.min(free);
                r.bram_blocks += granted;
                r.luts += 25 * granted; // address gen/enables per block
                if granted < demand {
                    // spilled partitions fall back to distributed ROM
                    r.bram_overflow = true;
                    let per_unit = blocks_for(patch_bits, depth).max(1);
                    let spilled_units = (demand - granted).div_ceil(per_unit);
                    r.luts += spilled_units * luts_for(patch_bits, depth);
                }
                r.flip_flops += 30 * granted; // per-block output registers
            }
            MemStyle::Lut => {
                r.luts += p.min(out_ch) * luts_for(patch_bits, depth);
            }
        }
        // folded-threshold ROM per conv channel (11-bit words)
        r.luts += luts_for(11, out_ch);
        // window mux: one 4:1 mux column per patch bit onto the broadcast
        // line, plus the stride/pad address generator
        r.luts += patch_bits.div_ceil(4) + 24;
        // patch shift register + patch/position counters
        r.flip_flops += patch_bits + 16;
    }
    r
}

/// The paper's published Vivado post-implementation values (Table 1),
/// `(LUT %, FF %, BRAM %)` → absolute counts against the device envelope.
pub fn vivado_anchor(parallelism: usize, style: MemStyle) -> Option<ResourceReport> {
    let (lut_pct, ff_pct, bram_pct) = match (parallelism, style) {
        (1, MemStyle::Bram) => (1.24, 0.36, 9.63),
        (1, MemStyle::Lut) => (3.92, 0.38, 0.0),
        (4, MemStyle::Bram) => (2.62, 0.39, 38.52),
        (4, MemStyle::Lut) => (10.49, 0.53, 0.0),
        (8, MemStyle::Bram) => (4.88, 0.48, 77.04),
        (8, MemStyle::Lut) => (20.43, 0.61, 0.0),
        (16, MemStyle::Bram) => (16.35, 4.51, 97.78),
        (16, MemStyle::Lut) => (21.74, 0.78, 0.0),
        (32, MemStyle::Bram) => (22.71, 12.53, 97.78),
        (32, MemStyle::Lut) => (18.20, 0.96, 0.0),
        (64, MemStyle::Bram) => (26.02, 8.41, 97.78),
        (64, MemStyle::Lut) => (24.09, 1.46, 0.0),
        (128, MemStyle::Lut) => (29.38, 2.48, 0.0),
        _ => return None,
    };
    Some(ResourceReport {
        luts: (lut_pct / 100.0 * Artix7_100T::LUTS as f64).round() as usize,
        flip_flops: (ff_pct / 100.0 * Artix7_100T::FLIP_FLOPS as f64).round() as usize,
        bram_blocks: (bram_pct / 100.0 * Artix7_100T::BRAM36 as f64).round() as usize,
        bram_overflow: style == MemStyle::Bram && parallelism >= 16,
        synthesizable: true,
    })
}

/// Anchored-when-known, modeled otherwise — what the table benches print.
pub fn best(dims: &[usize], parallelism: usize, style: MemStyle) -> ResourceReport {
    vivado_anchor(parallelism, style).unwrap_or_else(|| estimate(dims, parallelism, style))
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 4] = [784, 128, 64, 10];

    #[test]
    fn bram_demand_matches_paper_block_counts() {
        assert_eq!(bram_demand(&DIMS, 1), 13);
        assert_eq!(bram_demand(&DIMS, 4), 52);
        assert_eq!(bram_demand(&DIMS, 8), 104);
        assert_eq!(bram_demand(&DIMS, 16), 208); // > 132 ⇒ saturates
    }

    #[test]
    fn bram_pct_matches_table1() {
        for (p, want) in [(1usize, 9.63), (4, 38.52), (8, 77.04), (16, 97.78), (64, 97.78)] {
            let r = estimate(&DIMS, p, MemStyle::Bram);
            assert!(
                (r.bram_pct() - want).abs() < 0.05,
                "P={p}: {} vs {want}",
                r.bram_pct()
            );
        }
        assert_eq!(estimate(&DIMS, 8, MemStyle::Lut).bram_blocks, 0);
    }

    #[test]
    fn overflow_flag_tracks_saturation() {
        assert!(!estimate(&DIMS, 8, MemStyle::Bram).bram_overflow);
        assert!(estimate(&DIMS, 16, MemStyle::Bram).bram_overflow);
    }

    #[test]
    fn synthesizability_limits() {
        assert!(estimate(&DIMS, 64, MemStyle::Bram).synthesizable);
        assert!(!estimate(&DIMS, 128, MemStyle::Bram).synthesizable);
        assert!(estimate(&DIMS, 128, MemStyle::Lut).synthesizable);
        // (the 1..=128 domain is enforced by SimConfig; resources is total)
    }

    #[test]
    fn anchors_cover_the_13_rows() {
        let mut n = 0;
        for p in [1usize, 4, 8, 16, 32, 64, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                if vivado_anchor(p, style).is_some() {
                    n += 1;
                }
            }
        }
        assert_eq!(n, 13);
        assert!(vivado_anchor(128, MemStyle::Bram).is_none(), "BRAM@128 unsynthesizable");
        assert!(vivado_anchor(2, MemStyle::Bram).is_none());
    }

    #[test]
    fn anchor_percentages_roundtrip() {
        let a = vivado_anchor(64, MemStyle::Bram).unwrap();
        assert!((a.lut_pct() - 26.02).abs() < 0.01);
        assert!((a.ff_pct() - 8.41).abs() < 0.01);
        assert_eq!(a.bram_blocks, 132);
    }

    #[test]
    fn model_estimate_reduces_to_dims_estimate_without_conv() {
        let model = crate::bnn::random_model(&DIMS, 21);
        for p in [1usize, 8, 64] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                assert_eq!(estimate_model(&model, p, style), estimate(&DIMS, p, style));
            }
        }
        assert_eq!(bram_demand_model(&model, 4), bram_demand(&DIMS, 4));
    }

    #[test]
    fn conv_topology_adds_measurable_resources() {
        // mnist-style conv front: 8 channels of 3×3 over 28×28 pad 1
        let model =
            crate::bnn::random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 22);
        let dense_dims = [8 * 28 * 28, 64, 10];
        for p in [1usize, 8, 64] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                let conv = estimate_model(&model, p, style);
                let dense = estimate(&dense_dims, p, style);
                assert!(conv.luts > dense.luts, "P={p} {style:?}");
                assert!(conv.flip_flops > dense.flip_flops, "P={p} {style:?}");
                assert!(conv.luts < Artix7_100T::LUTS, "P={p} {style:?} fits");
            }
            assert!(
                bram_demand_model(&model, p) > bram_demand(&dense_dims, p),
                "P={p}"
            );
        }
        // BRAM style caps at the usable block budget
        let r = estimate_model(&model, 64, MemStyle::Bram);
        assert!(r.bram_blocks <= Artix7_100T::BRAM36_USABLE);
    }

    #[test]
    fn model_tracks_anchor_direction() {
        // the model must at least grow LUTs with P in BRAM style and keep
        // FF usage far below device limits — the paper's qualitative claims
        let low = estimate(&DIMS, 1, MemStyle::Bram);
        let high = estimate(&DIMS, 64, MemStyle::Bram);
        assert!(high.luts > low.luts);
        assert!(high.ff_pct() < 20.0);
        // structural model within a sanity envelope of every anchor — Vivado
        // logic folding cannot be forward-modeled exactly (§4.2.3), so the
        // envelope is deliberately loose; benches print anchors.
        for p in [1usize, 4, 8, 16, 32, 64] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                let m = estimate(&DIMS, p, style);
                let a = vivado_anchor(p, style).unwrap();
                let ratio = m.luts as f64 / a.luts as f64;
                assert!((0.25..=3.6).contains(&ratio), "P={p} {style:?} ratio {ratio}");
            }
        }
    }
}
