//! # bnn-fpga — Binary Neural Network inference accelerator (reproduction)
//!
//! Reproduction of *"Binary Neural Network Implementation for Handwritten
//! Digit Recognition on FPGA"* (Ertörer & Ünsalan, CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * [`bnn`] — bit-packed XNOR-popcount inference library (the paper's
//!   Algorithm 1 in software, `z = n − 2·popcount(x ⊕ w)`).
//! * [`sim`] — cycle-accurate simulator of the paper's Verilog design:
//!   FSM-controlled datapath, dual-port BRAM / LUT-ROM memories, argmax,
//!   seven-segment output, parameterized parallelism (1..128).
//! * [`estimate`] — analytical Vivado-substitute models (LUT/FF/BRAM,
//!   power, thermal, timing slack, ASIC/GPU comparisons).
//! * [`runtime`] — PJRT loader/executor for the AOT HLO artifacts the
//!   Python build path emits (`make artifacts`); Python never runs on the
//!   request path.
//! * [`coordinator`] — serving layer: request router + dynamic batcher over
//!   interchangeable backends (native / PJRT / FPGA-sim), worker threads,
//!   metrics.
//! * [`mem`], [`data`] — the paper's `.mem`/idx interchange formats and the
//!   synthetic-MNIST dataset substrate.
//! * [`util`], [`config`], [`cli`] — first-party infrastructure (PRNG,
//!   JSON, stats, bench harness, property testing, TOML-subset config,
//!   argument parsing) — the offline environment has no crates.io access.

pub mod bnn;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimate;
pub mod mem;
pub mod runtime;
pub mod sim;
pub mod util;

/// Canonical network architecture of the paper (§3.1): 784-128-64-10.
pub const BNN_DIMS: [usize; 4] = [784, 128, 64, 10];

/// The paper's clock target (§3.5): 80 MHz ⇒ 12.5 ns per cycle.
pub const CLOCK_HZ: u64 = 80_000_000;

/// Nanoseconds per clock cycle at the 80 MHz design point.
pub const NS_PER_CYCLE: f64 = 1e9 / CLOCK_HZ as f64;

/// Default artifacts directory produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("BNN_FPGA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
