//! # bnn-fpga — Binary Neural Network inference accelerator (reproduction)
//!
//! Reproduction of *"Binary Neural Network Implementation for Handwritten
//! Digit Recognition on FPGA"* (Ertörer & Ünsalan, CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * [`bnn`] — bit-packed XNOR-popcount inference library (the paper's
//!   Algorithm 1 in software, `z = n − 2·popcount(x ⊕ w)`), with a scalar
//!   reference kernel, a blocked multi-row kernel (the software mirror
//!   of the FPGA's parallelism parameter), a weight-stationary batch-tiled
//!   kernel, and a runtime-dispatched SIMD tier (AVX2/NEON with a
//!   guaranteed portable fallback) — all bit-identical, pinned by the
//!   golden-vector + differential conformance suite.
//! * [`sim`] — cycle-accurate simulator of the paper's Verilog design:
//!   FSM-controlled datapath, dual-port BRAM / LUT-ROM memories, argmax,
//!   seven-segment output, parameterized parallelism (1..128).
//! * [`estimate`] — analytical Vivado-substitute models (LUT/FF/BRAM,
//!   power, thermal, timing slack, ASIC/GPU comparisons).
//! * [`runtime`] — PJRT loader/executor for the AOT HLO artifacts the
//!   Python build path emits (`make artifacts`); Python never runs on the
//!   request path.
//! * [`coordinator`] — serving layer behind one typed construction path,
//!   [`coordinator::Engine`]`::builder()`: request router + dynamic
//!   batcher over interchangeable backends (native / PJRT / FPGA-sim),
//!   ticketed submissions with per-request options, a single-queue core
//!   and a sharded multi-worker core (one backend replica + metrics per
//!   worker), and a TCP wire server speaking protocol v1 and the
//!   batched, id-echoing v2.
//! * [`mem`], [`data`] — the paper's `.mem`/idx interchange formats and the
//!   synthetic-MNIST dataset substrate.
//! * [`util`], [`config`], [`cli`] — first-party infrastructure (PRNG,
//!   JSON, stats, bench harness, property testing, TOML-subset config,
//!   argument parsing) — the offline environment has no crates.io access.

pub mod bnn;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimate;
pub mod mem;
pub mod runtime;
pub mod sim;
pub mod util;

/// Canonical network architecture of the paper (§3.1): 784-128-64-10.
pub const BNN_DIMS: [usize; 4] = [784, 128, 64, 10];

/// The paper's clock target (§3.5): 80 MHz ⇒ 12.5 ns per cycle.
pub const CLOCK_HZ: u64 = 80_000_000;

/// Nanoseconds per clock cycle at the 80 MHz design point.
pub const NS_PER_CYCLE: f64 = 1e9 / CLOCK_HZ as f64;

/// Default artifacts directory produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("BNN_FPGA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Load the trained model and the paper's §4.1 subset from
/// [`artifacts_dir`], falling back to a deterministic random model plus
/// `n_synth` synthetic digits when `make artifacts` has not run.
///
/// Returns `(model, dataset, trained)`.  With `trained == false` the
/// predictions are chance-level, but kernel equivalence, cycle counts,
/// serving mechanics and every throughput number are unaffected — which is
/// what lets the examples, benches and most tests run artifact-free.
pub fn load_model_or_synth(n_synth: usize) -> (bnn::BnnModel, data::Dataset, bool) {
    let dir = artifacts_dir();
    if let (Ok(model), Ok(ds)) = (
        mem::load_model(&dir.join("weights.json")),
        data::Dataset::load_mem_subset(&dir.join("mem")),
    ) {
        return (model, ds, true);
    }
    (
        bnn::model::random_model(&BNN_DIMS, 0xB17),
        data::synth::generate_dataset(n_synth.max(1), 0xDA7A),
        false,
    )
}
