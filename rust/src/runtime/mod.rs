//! PJRT runtime — loads the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched; Python never runs on
//! the request path (the artifacts are self-contained — trained weights are
//! baked in as constants).  Interchange is HLO *text*: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedModel};
pub use manifest::{ArtifactSpec, Dtype, Manifest};
