//! The PJRT execution engine: compile-once, execute-many.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! ## Thread-safety design
//!
//! The `xla 0.1.6` wrapper types are `!Send`/`!Sync` (Rc + raw PJRT
//! pointers), so the engine keeps **all** PJRT state behind one internal
//! mutex and never lets client/executable handles escape.  Calls are
//! serialized at this boundary; PJRT-CPU parallelizes internally with its
//! own thread pool, so serializing the dispatch does not serialize the
//! compute.  `unsafe impl Send + Sync` is sound because (a) every access
//! path takes the mutex, and (b) the `Rc` clones never leave the guarded
//! struct, so cross-thread reference-count races cannot occur.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, Manifest};

struct Inner {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Compile-once engine over an artifact directory.  Cheap to share via
/// `Arc<Engine>`; all methods take `&self`.
pub struct Engine {
    pub manifest: Manifest,
    inner: Mutex<Inner>,
    platform: String,
}

// SAFETY: see module docs — all `!Send` PJRT state lives inside `inner`
// and is only touched while holding the mutex; no handle escapes.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// Borrowed view of an artifact's signature (safe to hand out).
pub struct LoadedModel {
    pub name: String,
    pub batch: usize,
    pub input_elements: usize,
    pub output_elements: usize,
}

impl Engine {
    /// Create a CPU PJRT client and read the manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        Ok(Engine {
            manifest,
            inner: Mutex::new(Inner {
                client,
                cache: HashMap::new(),
            }),
            platform,
        })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Compile (or fetch the cached) artifact, returning its signature.
    pub fn prepare(&self, name: &str) -> Result<LoadedModel> {
        let spec = self.manifest.get(name)?.clone();
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", spec.name))?;
            inner.cache.insert(name.to_string(), exe);
        }
        Ok(LoadedModel {
            name: spec.name.clone(),
            batch: spec.batch,
            input_elements: spec.input.elements(),
            output_elements: spec.output.elements(),
        })
    }

    /// Pre-compile every artifact of a model family (warm start for serving).
    pub fn warm(&self, model: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.prepare(n)?;
        }
        Ok(names.len())
    }

    /// Execute a u32→i32 artifact (BNN: packed bits in, integer logits out).
    pub fn run_u32_to_i32(&self, name: &str, input: &[u32]) -> Result<Vec<i32>> {
        let spec = self.manifest.get(name)?;
        if spec.input.dtype != Dtype::U32 || spec.output.dtype != Dtype::I32 {
            bail!("artifact {name} is not u32→i32");
        }
        self.check_len(name, spec.input.elements(), input.len())?;
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U32,
            &spec.input.shape,
            pod_bytes(input),
        )?;
        let out = self.execute_one(name, lit)?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Execute a u32→i32 artifact into a caller-owned output slice — the
    /// flat-logits serving path ([`crate::coordinator::LogitsBuf`]).
    ///
    /// `out` receives the first `out.len()` output elements, which lets a
    /// ladder-padded execution (artifact batch > request batch) drop the
    /// padding rows without a per-row copy into fresh `Vec`s.  Note this
    /// path is *not* allocation-free: the `xla 0.1.6` decode surface only
    /// offers `Literal::to_vec`, so one `exec_batch × n_classes` `Vec<i32>`
    /// is still materialized per executed batch (per batch, not per
    /// request) before the copy into `out`.
    pub fn run_u32_to_i32_into(&self, name: &str, input: &[u32], out: &mut [i32]) -> Result<()> {
        let logits = self.run_u32_to_i32(name, input)?;
        anyhow::ensure!(
            logits.len() >= out.len(),
            "artifact {name} produced {} elements, caller expects ≥ {}",
            logits.len(),
            out.len()
        );
        out.copy_from_slice(&logits[..out.len()]);
        Ok(())
    }

    /// Execute an f32→f32 artifact (CNN baseline).
    pub fn run_f32_to_f32(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let spec = self.manifest.get(name)?;
        if spec.input.dtype != Dtype::F32 || spec.output.dtype != Dtype::F32 {
            bail!("artifact {name} is not f32→f32");
        }
        self.check_len(name, spec.input.elements(), input.len())?;
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &spec.input.shape,
            pod_bytes(input),
        )?;
        let out = self.execute_one(name, lit)?;
        Ok(out.to_vec::<f32>()?)
    }

    fn check_len(&self, name: &str, want: usize, got: usize) -> Result<()> {
        if got != want {
            bail!("artifact {name} expects {want} input elements, got {got}");
        }
        Ok(())
    }

    fn execute_one(&self, name: &str, lit: xla::Literal) -> Result<xla::Literal> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(name) {
            drop(inner);
            self.prepare(name)?;
            inner = self.inner.lock().unwrap();
        }
        let exe = inner.cache.get(name).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&[lit])?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        Ok(out.to_tuple1()?)
    }
}

/// Byte view of a POD slice (no bytemuck crate offline).
fn pod_bytes<T: Copy>(v: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

// NOTE: integration coverage for the engine lives in rust/tests/integration.rs
// (requires `make artifacts`); unit tests here cover the byte casts only.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_casts_are_little_endian_pod() {
        assert_eq!(pod_bytes(&[1u32]), &[1, 0, 0, 0]);
        assert_eq!(pod_bytes(&[1.0f32]), 1.0f32.to_le_bytes());
        assert_eq!(pod_bytes::<u32>(&[]).len(), 0);
    }
}
