//! `artifacts/manifest.json` — the artifact registry contract between the
//! Python AOT path and the Rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    U32,
    I32,
    F32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "u32" => Dtype::U32,
            "i32" => Dtype::I32,
            "f32" => Dtype::F32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }
}

/// Tensor signature: shape + dtype.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled-artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// "bnn" | "cnn".
    pub model: String,
    pub batch: usize,
    pub file: PathBuf,
    pub input: TensorSig,
    pub output: TensorSig,
}

/// The parsed registry.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_sig(j: &Json) -> Result<TensorSig> {
    let shape = j
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSig {
        shape,
        dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
    })
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in root.get("artifacts")?.as_arr()? {
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                model: a.get("model")?.as_str()?.to_string(),
                batch: a.get("batch")?.as_usize()?,
                file: artifacts_dir.join(a.get("file")?.as_str()?),
                input: parse_sig(a.get("input")?)?,
                output: parse_sig(a.get("output")?)?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Batch sizes available for a model, ascending — the dynamic batcher's
    /// ladder.
    pub fn batch_ladder(&self, model: &str) -> Vec<usize> {
        let mut ladder: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.batch)
            .collect();
        ladder.sort_unstable();
        ladder.dedup();
        ladder
    }

    /// Artifact name for `(model, batch)`.
    pub fn name_for(&self, model: &str, batch: usize) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.batch == batch)
            .map(|a| a.name.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    const SAMPLE: &str = r#"{"artifacts": [
      {"name": "bnn_b1", "model": "bnn", "batch": 1, "file": "bnn_b1.hlo.txt",
       "input": {"shape": [1, 25], "dtype": "u32"},
       "output": {"shape": [1, 10], "dtype": "i32"}},
      {"name": "bnn_b8", "model": "bnn", "batch": 8, "file": "bnn_b8.hlo.txt",
       "input": {"shape": [8, 25], "dtype": "u32"},
       "output": {"shape": [8, 10], "dtype": "i32"}},
      {"name": "cnn_b1", "model": "cnn", "batch": 1, "file": "cnn_b1.hlo.txt",
       "input": {"shape": [1, 784], "dtype": "f32"},
       "output": {"shape": [1, 10], "dtype": "f32"}}
    ]}"#;

    #[test]
    fn parses_and_indexes() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_manifest");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.get("bnn_b8").unwrap().batch, 8);
        assert_eq!(m.batch_ladder("bnn"), vec![1, 8]);
        assert_eq!(m.name_for("cnn", 1), Some("cnn_b1"));
        assert_eq!(m.name_for("cnn", 8), None);
        assert!(m.get("nope").is_err());
        let sig = &m.get("bnn_b1").unwrap().input;
        assert_eq!(sig.elements(), 25);
        assert_eq!(sig.dtype, Dtype::U32);
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_manifest_none");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn bad_dtype_rejected() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_manifest_bad");
        write_manifest(
            &dir,
            r#"{"artifacts": [{"name": "x", "model": "bnn", "batch": 1, "file": "x",
                "input": {"shape": [1], "dtype": "f16"},
                "output": {"shape": [1], "dtype": "i32"}}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }
}
