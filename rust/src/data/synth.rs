//! Rust-side synthetic digit generator — an independent mirror of
//! `python/compile/data.py` (same stroke-template approach, independent
//! implementation) so Rust tests, examples and the accelerator demo run
//! without the Python build path.  Not bit-identical to the Python
//! generator; the shared interchange is the idx files under `artifacts/`.

use crate::bnn::packing::Packed;
use crate::util::prng::Xoshiro256;

use super::Dataset;

const IMG: usize = 28;

/// Polyline skeletons per digit on the unit square (y down).
fn templates(digit: u8) -> &'static [&'static [(f32, f32)]] {
    match digit {
        0 => &[&[(0.50, 0.08), (0.78, 0.22), (0.82, 0.50), (0.76, 0.78), (0.50, 0.92),
                (0.24, 0.78), (0.18, 0.50), (0.22, 0.22), (0.50, 0.08)]],
        1 => &[&[(0.35, 0.25), (0.55, 0.10), (0.55, 0.90)], &[(0.35, 0.90), (0.75, 0.90)]],
        2 => &[&[(0.22, 0.28), (0.35, 0.12), (0.62, 0.10), (0.78, 0.26), (0.74, 0.45),
                (0.45, 0.65), (0.22, 0.88), (0.80, 0.88)]],
        3 => &[&[(0.24, 0.16), (0.55, 0.10), (0.76, 0.24), (0.66, 0.44), (0.45, 0.50),
                (0.68, 0.56), (0.78, 0.76), (0.55, 0.92), (0.24, 0.84)]],
        4 => &[&[(0.62, 0.90), (0.62, 0.10), (0.20, 0.62), (0.82, 0.62)]],
        5 => &[&[(0.76, 0.12), (0.30, 0.12), (0.26, 0.46), (0.58, 0.42), (0.78, 0.58),
                (0.74, 0.82), (0.48, 0.92), (0.24, 0.82)]],
        6 => &[&[(0.68, 0.10), (0.40, 0.26), (0.26, 0.52), (0.28, 0.78), (0.50, 0.92),
                (0.72, 0.80), (0.74, 0.60), (0.54, 0.48), (0.32, 0.56)]],
        7 => &[&[(0.20, 0.12), (0.80, 0.12), (0.48, 0.90)], &[(0.34, 0.52), (0.66, 0.52)]],
        8 => &[&[(0.50, 0.10), (0.72, 0.20), (0.70, 0.40), (0.50, 0.50), (0.30, 0.40),
                (0.28, 0.20), (0.50, 0.10)],
               &[(0.50, 0.50), (0.74, 0.62), (0.72, 0.84), (0.50, 0.92), (0.28, 0.84),
                (0.26, 0.62), (0.50, 0.50)]],
        9 => &[&[(0.72, 0.40), (0.52, 0.50), (0.30, 0.40), (0.28, 0.20), (0.50, 0.10),
                (0.70, 0.18), (0.72, 0.40), (0.70, 0.66), (0.56, 0.90), (0.36, 0.88)]],
        _ => panic!("digit out of range"),
    }
}

/// Render one perturbed digit as a grayscale f32 image in [0, 1].
pub fn render(digit: u8, rng: &mut Xoshiro256) -> Vec<f32> {
    let ang = rng.uniform(-0.40, 0.40);
    let sx = rng.uniform(0.62, 1.10) as f32;
    let sy = rng.uniform(0.62, 1.10) as f32;
    let shear = rng.uniform(-0.27, 0.27) as f32;
    let (ca, sa) = (ang.cos() as f32, ang.sin() as f32);
    // m = rot * scale-shear
    let m = [
        [ca * sx, ca * shear * sx - sa * sy],
        [sa * sx, sa * shear * sx + ca * sy],
    ];
    let tx = rng.uniform(-0.11, 0.11) as f32 + 0.5 - (m[0][0] * 0.5 + m[0][1] * 0.5);
    let ty = rng.uniform(-0.11, 0.11) as f32 + 0.5 - (m[1][0] * 0.5 + m[1][1] * 0.5);
    let thick = rng.uniform(0.7, 2.1) as f32;

    let mut img = vec![0f32; IMG * IMG];
    for stroke in templates(digit) {
        let pts: Vec<(f32, f32)> = stroke
            .iter()
            .map(|&(x, y)| {
                let jx = x + (rng.normal() * 0.028) as f32;
                let jy = y + (rng.normal() * 0.028) as f32;
                (m[0][0] * jx + m[0][1] * jy + tx, m[1][0] * jx + m[1][1] * jy + ty)
            })
            .collect();
        for seg in pts.windows(2) {
            let (ax, ay) = seg[0];
            let (bx, by) = seg[1];
            let (dx, dy) = (bx - ax, by - ay);
            let denom = (dx * dx + dy * dy).max(1e-9);
            for r in 0..IMG {
                for c in 0..IMG {
                    let px = (c as f32 + 0.5) / IMG as f32;
                    let py = (r as f32 + 0.5) / IMG as f32;
                    let t = (((px - ax) * dx + (py - ay) * dy) / denom).clamp(0.0, 1.0);
                    let ddx = px - (ax + t * dx);
                    let ddy = py - (ay + t * dy);
                    let d = (ddx * ddx + ddy * ddy).sqrt() * IMG as f32;
                    let v = (1.6 * thick - d).clamp(0.0, 1.0);
                    let cell = &mut img[r * IMG + c];
                    if v > *cell {
                        *cell = v;
                    }
                }
            }
        }
    }
    let gain = rng.uniform(0.6, 1.0) as f32;
    for v in img.iter_mut() {
        *v = (*v * gain + (rng.normal() * 0.095) as f32).clamp(0.0, 1.0);
    }
    img
}

/// Generate a balanced, shuffled, binarized+packed dataset.
pub fn generate_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let mut labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    rng.shuffle(&mut labels);
    let images = labels
        .iter()
        .map(|&l| {
            let img = render(l, &mut rng);
            let bits: Vec<u8> = img.iter().map(|&p| u8::from(p >= 0.5)).collect();
            Packed::from_bits(&bits)
        })
        .collect();
    Dataset { images, labels }
}

/// Render one digit to an ASCII art string (demos/debugging).
pub fn ascii_digit(packed: &Packed) -> String {
    let bits = packed.to_bits();
    let mut out = String::with_capacity(IMG * (IMG + 1));
    for r in 0..IMG {
        for c in 0..IMG {
            out.push(if bits[r * IMG + c] == 1 { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate_dataset(20, 9);
        let b = generate_dataset(20, 9);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images[0].words, b.images[0].words);
        let c = generate_dataset(20, 10);
        assert_ne!(a.images[0].words, c.images[0].words);
    }

    #[test]
    fn balanced_classes() {
        let ds = generate_dataset(100, 3);
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn images_have_ink_but_not_too_much() {
        let ds = generate_dataset(50, 4);
        for img in &ds.images {
            let ink: u32 = img.to_bits().iter().map(|&b| b as u32).sum();
            assert!(ink > 15, "digit with almost no ink ({ink} px)");
            assert!(ink < 500, "digit nearly solid ({ink} px)");
        }
    }

    #[test]
    fn ascii_render_shape() {
        let ds = generate_dataset(1, 5);
        let art = ascii_digit(&ds.images[0]);
        assert_eq!(art.lines().count(), 28);
        assert!(art.contains('#'));
    }
}
