//! Dataset management: loads the build-time idx files, binarizes and packs
//! images for the inference paths; [`synth`] is an independent Rust-side
//! generator for artifact-free tests and demos.

pub mod synth;

use std::path::Path;

use anyhow::{bail, Result};

use crate::bnn::packing::pack_bits_u64;
use crate::bnn::packing::Packed;
use crate::mem;

/// An in-memory labelled digit dataset (binarized + packed).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Packed 784-bit images (u64 words).
    pub images: Vec<Packed>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Binarize one grayscale u8 image (paper §3.1: p ≥ 128 ⇔ 2p/255−1 ≥ 0).
    pub fn binarize_u8(pixels: &[u8]) -> Vec<u8> {
        pixels.iter().map(|&p| u8::from(p >= 128)).collect()
    }

    /// Load the test split from an artifacts `data/` directory (idx files).
    pub fn load_idx_test(data_dir: &Path) -> Result<Dataset> {
        let (imgs, rows, cols) = mem::read_idx_images(&data_dir.join("t10k-images-idx3-ubyte"))?;
        let labels = mem::read_idx_labels(&data_dir.join("t10k-labels-idx1-ubyte"))?;
        if imgs.len() != labels.len() {
            bail!("{} images vs {} labels", imgs.len(), labels.len());
        }
        if rows * cols != 784 {
            bail!("expected 28×28 images, got {rows}×{cols}");
        }
        let images = imgs
            .iter()
            .map(|img| Packed {
                words: pack_bits_u64(&Self::binarize_u8(img)),
                n_bits: 784,
            })
            .collect();
        Ok(Dataset {
            images,
            labels,
        })
    }

    /// Load the paper's §4.1 100-image subset from the exported `.mem` files.
    pub fn load_mem_subset(mem_dir: &Path) -> Result<Dataset> {
        let images_w = mem::read_image_mem(&mem_dir.join("images_100.mem"), 784)?;
        let labels = mem::read_label_mem(&mem_dir.join("labels_100.mem"))?;
        if images_w.len() != labels.len() {
            bail!("{} images vs {} labels", images_w.len(), labels.len());
        }
        Ok(Dataset {
            images: images_w
                .into_iter()
                .map(|words| Packed { words, n_bits: 784 })
                .collect(),
            labels,
        })
    }

    /// Flatten a range of images into a contiguous u64 batch buffer.
    pub fn batch_words(&self, start: usize, count: usize) -> Vec<u64> {
        let mut out = Vec::with_capacity(count * self.images[0].words.len());
        for i in start..start + count {
            out.extend_from_slice(&self.images[i].words);
        }
        out
    }

    /// Flatten a range into the u32 interchange layout (PJRT input).
    pub fn batch_words_u32(&self, start: usize, count: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for i in start..start + count {
            out.extend(self.images[i].to_u32_words());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binarize_threshold() {
        assert_eq!(Dataset::binarize_u8(&[0, 127, 128, 255]), vec![0, 0, 1, 1]);
    }

    #[test]
    fn synth_dataset_loads_and_batches() {
        let ds = synth::generate_dataset(30, 42);
        assert_eq!(ds.len(), 30);
        assert!(ds.labels.iter().all(|&l| l < 10));
        let batch = ds.batch_words(0, 3);
        assert_eq!(batch.len(), 3 * ds.images[0].words.len());
        let b32 = ds.batch_words_u32(0, 3);
        assert_eq!(b32.len(), 3 * 25);
    }
}
