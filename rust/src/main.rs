//! `bnn-fpga` binary entrypoint — all behavior lives in [`bnn_fpga::cli`].

fn main() {
    bnn_fpga::cli::run();
}
