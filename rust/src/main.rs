fn main() {
    bnn_fpga::cli::run();
}
