//! Seven-segment display decoder (§3.3: "a seven-segment display decoder
//! converts the predicted digit into display signals").
//!
//! Matches the Nexys A7's common-anode convention: segments are
//! **active-low**, bit order `{g, f, e, d, c, b, a}` (bit 0 = segment a).

/// Active-low segment pattern for a digit (0–9).  Panics on non-digits —
/// the classifier can only produce 0..=9.
pub fn decode(digit: u8) -> u8 {
    // active-high truth table first, then invert; bit0=a .. bit6=g
    let on: u8 = match digit {
        0 => 0b011_1111,
        1 => 0b000_0110,
        2 => 0b101_1011,
        3 => 0b100_1111,
        4 => 0b110_0110,
        5 => 0b110_1101,
        6 => 0b111_1101,
        7 => 0b000_0111,
        8 => 0b111_1111,
        9 => 0b110_1111,
        _ => panic!("seven-segment decoder: digit {digit} out of range"),
    };
    !on & 0x7F
}

/// Inverse mapping used by tests and the display capture in the demo.
pub fn encode(pattern_active_low: u8) -> Option<u8> {
    (0..=9).find(|&d| decode(d) == pattern_active_low)
}

/// Render the segment pattern as 3-line ASCII art (demo output).
pub fn ascii(pattern_active_low: u8) -> String {
    let on = |seg: u8| pattern_active_low & (1 << seg) == 0; // active low
    let a = if on(0) { " _ " } else { "   " };
    let f = if on(5) { "|" } else { " " };
    let g = if on(6) { "_" } else { " " };
    let b = if on(1) { "|" } else { " " };
    let e = if on(4) { "|" } else { " " };
    let d = if on(3) { "_" } else { " " };
    let c = if on(2) { "|" } else { " " };
    format!("{a}\n{f}{g}{b}\n{e}{d}{c}\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_digits_distinct_and_invertible() {
        let mut seen = std::collections::HashSet::new();
        for d in 0..=9u8 {
            let p = decode(d);
            assert!(seen.insert(p), "pattern collision for {d}");
            assert_eq!(encode(p), Some(d));
            assert_eq!(p & 0x80, 0, "only 7 bits used");
        }
    }

    #[test]
    fn known_patterns() {
        // 0: all segments except g → active-low 0b100_0000
        assert_eq!(decode(0), 0b100_0000);
        // 8: everything on → 0
        assert_eq!(decode(8), 0);
        // 1: b, c only
        assert_eq!(decode(1), !0b000_0110u8 & 0x7F);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_non_digit()
    {
        decode(10);
    }

    #[test]
    fn ascii_has_three_lines() {
        let art = ascii(decode(7));
        assert_eq!(art.lines().count(), 3);
        assert!(art.contains('_'));
    }
}
