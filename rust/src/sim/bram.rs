//! Dual-port block-RAM model (§3.3: "dual-port BRAMs ... chosen for weight
//! storage due to their high density and dual-port capability").
//!
//! Models the Artix-7 RAMB36E1 primitive at the level the accelerator
//! needs: synchronous reads with one-cycle latency, two independent read
//! ports, and block-count accounting (a `width × depth` ROM occupies
//! `ceil(width/72) × ceil(depth/512)` blocks in the widest SDP mode).
//! Access counts feed the activity-based power model.

/// Capacity of one RAMB36 block in bits.
pub const BRAM36_BITS: usize = 36 * 1024;
/// Maximum simple-dual-port width of one block.
pub const BRAM36_MAX_WIDTH: usize = 72;
/// Depth at maximum width.
pub const BRAM36_DEPTH_AT_MAX_WIDTH: usize = 512;

/// Blocks required for a `width × depth` ROM (width-sliced, then depth).
pub fn blocks_for(width_bits: usize, depth: usize) -> usize {
    let width_slices = width_bits.div_ceil(BRAM36_MAX_WIDTH);
    let depth_slices = depth.div_ceil(BRAM36_DEPTH_AT_MAX_WIDTH);
    width_slices * depth_slices
}

/// A weight ROM backed by dual-port BRAM: `depth` rows of `width_bits`,
/// stored as packed u64 words per row.
#[derive(Clone, Debug)]
pub struct DualPortBram {
    pub width_bits: usize,
    pub depth: usize,
    words_per_row: usize,
    data: Vec<u64>,
    /// Pending synchronous reads (port → row latched last cycle).
    pending: [Option<usize>; 2],
    pub reads: u64,
    pub read_bits: u64,
}

impl DualPortBram {
    /// Build from row-major packed rows.
    pub fn new(width_bits: usize, rows: &[&[u64]]) -> Self {
        let words_per_row = width_bits.div_ceil(64);
        let mut data = Vec::with_capacity(rows.len() * words_per_row);
        for r in rows {
            assert_eq!(r.len(), words_per_row, "row word count");
            data.extend_from_slice(r);
        }
        Self {
            width_bits,
            depth: rows.len(),
            words_per_row,
            data,
            pending: [None, None],
            reads: 0,
            read_bits: 0,
        }
    }

    pub fn blocks(&self) -> usize {
        blocks_for(self.width_bits, self.depth)
    }

    /// Issue a synchronous read on `port` (0 or 1); data is visible after
    /// the next [`Self::clock`] via [`Self::output`].
    pub fn issue_read(&mut self, port: usize, row: usize) {
        assert!(port < 2, "dual-port: port {port}");
        assert!(row < self.depth, "row {row} >= depth {}", self.depth);
        self.pending[port] = Some(row);
    }

    /// Advance one clock: latch pending reads into the output registers.
    /// Returns the rows now visible on each port.
    pub fn clock(&mut self) -> [Option<usize>; 2] {
        let out = self.pending;
        for p in out.iter().flatten() {
            self.reads += 1;
            self.read_bits += self.width_bits as u64;
            let _ = p;
        }
        self.pending = [None, None];
        out
    }

    /// Combinational view of a row's packed words (the registered output
    /// the datapath consumes after `clock`).
    pub fn row_words(&self, row: usize) -> &[u64] {
        &self.data[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    /// Read a single weight bit (column `bit` of `row`) — the per-cycle
    /// datapath access pattern in the bit-serial inner loop.
    #[inline]
    pub fn bit(&self, row: usize, bit: usize) -> u8 {
        debug_assert!(bit < self.width_bits);
        ((self.data[row * self.words_per_row + bit / 64] >> (bit % 64)) & 1) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_counts_match_paper_layout() {
        // Paper P=1: layer1 784×128 → 11 blocks, layer2 128×64 → 2,
        // (layer3 64×10 lives in LUT-ROM) ⇒ 13 total ⇒ 9.63 % of 135.
        assert_eq!(blocks_for(784, 128), 11);
        assert_eq!(blocks_for(128, 64), 2);
        assert_eq!((11 + 2) as f64 / 135.0 * 100.0, 9.62962962962963);
    }

    #[test]
    fn deep_roms_need_depth_slices() {
        assert_eq!(blocks_for(72, 512), 1);
        assert_eq!(blocks_for(72, 513), 2);
        assert_eq!(blocks_for(73, 512), 2);
    }

    #[test]
    fn synchronous_read_latency() {
        let rows: Vec<Vec<u64>> = vec![vec![0xAA], vec![0x55]];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut bram = DualPortBram::new(8, &refs);
        bram.issue_read(0, 1);
        assert_eq!(bram.reads, 0, "no data before clock edge");
        let out = bram.clock();
        assert_eq!(out[0], Some(1));
        assert_eq!(bram.row_words(1), &[0x55]);
        assert_eq!(bram.reads, 1);
        assert_eq!(bram.read_bits, 8);
    }

    #[test]
    fn dual_ports_are_independent() {
        let rows: Vec<Vec<u64>> = (0..4).map(|i| vec![i as u64]).collect();
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut bram = DualPortBram::new(4, &refs);
        bram.issue_read(0, 2);
        bram.issue_read(1, 3);
        let out = bram.clock();
        assert_eq!(out, [Some(2), Some(3)]);
        assert_eq!(bram.reads, 2);
    }

    #[test]
    fn bit_extraction() {
        let rows: Vec<Vec<u64>> = vec![vec![0b1010]];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let bram = DualPortBram::new(4, &refs);
        assert_eq!(bram.bit(0, 0), 0);
        assert_eq!(bram.bit(0, 1), 1);
        assert_eq!(bram.bit(0, 3), 1);
    }

    #[test]
    #[should_panic(expected = "row")]
    fn out_of_range_read_panics() {
        let rows: Vec<Vec<u64>> = vec![vec![0]];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut bram = DualPortBram::new(4, &refs);
        bram.issue_read(0, 1);
    }
}
