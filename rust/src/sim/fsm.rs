//! The centralized inference FSM (§3.4).
//!
//! Five sequential stages — first hidden layer, second hidden layer, output
//! accumulation, argmax classification, completion — with per-group
//! sub-states: weight-row latch (`GroupLoad`), the bit-serial
//! XNOR-popcount inner loop (`ComputeBit`), and threshold/score writeback
//! (`GroupWriteback`).  "Internal counters, control flags, and
//! synchronization signals" (§3.4) are the `layer/group/bit/step` indices
//! carried in the state.

/// FSM state.  One [`super::top::Accelerator::tick`] call = one clock cycle
/// in exactly one of these states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsmState {
    /// Reset / waiting for `start`.
    Idle,
    /// Latch the 784-bit input row from the image ROM.  BRAM style spends
    /// 2 cycles here (synchronous read latency), LUT style 1 (§4.2.1's
    /// constant 10 ns style delta).
    LoadImage { substep: u8 },
    /// Per-layer FSM transition / counter initialization.
    LayerPrologue { layer: u8 },
    /// Latch the ≤P weight rows of the current neuron group.
    GroupLoad { layer: u8, group: u16 },
    /// Broadcast input bit `bit` to all active units (1 bit / cycle).
    ComputeBit { layer: u8, group: u16, bit: u16 },
    /// Threshold-compare + activation latch (hidden) or score latch (output).
    GroupWriteback { layer: u8, group: u16 },
    /// Iterative 10-way comparison (§3.4), one class per cycle.
    Argmax { step: u8 },
    /// Result latched to the seven-segment decoder; held until reset.
    Done,
}

impl FsmState {
    /// Coarse stage name for trace output / cycle accounting.
    pub fn stage(&self) -> &'static str {
        match self {
            FsmState::Idle => "idle",
            FsmState::LoadImage { .. } => "load",
            FsmState::LayerPrologue { .. } => "prologue",
            FsmState::GroupLoad { .. } => "group_load",
            FsmState::ComputeBit { .. } => "compute",
            FsmState::GroupWriteback { .. } => "writeback",
            FsmState::Argmax { .. } => "argmax",
            FsmState::Done => "done",
        }
    }
}

/// Per-stage cycle accounting (for traces, EXPERIMENTS.md and the power
/// model's activity factors).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CycleBreakdown {
    pub load: u64,
    pub prologue: u64,
    pub group_load: u64,
    pub compute: u64,
    pub writeback: u64,
    pub argmax: u64,
    pub done: u64,
}

impl CycleBreakdown {
    pub fn record(&mut self, s: &FsmState) {
        match s {
            FsmState::Idle => {}
            FsmState::LoadImage { .. } => self.load += 1,
            FsmState::LayerPrologue { .. } => self.prologue += 1,
            FsmState::GroupLoad { .. } => self.group_load += 1,
            FsmState::ComputeBit { .. } => self.compute += 1,
            FsmState::GroupWriteback { .. } => self.writeback += 1,
            FsmState::Argmax { .. } => self.argmax += 1,
            FsmState::Done => self.done += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.load
            + self.prologue
            + self.group_load
            + self.compute
            + self.writeback
            + self.argmax
            + self.done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let mut b = CycleBreakdown::default();
        b.record(&FsmState::LoadImage { substep: 0 });
        b.record(&FsmState::ComputeBit { layer: 0, group: 0, bit: 3 });
        b.record(&FsmState::ComputeBit { layer: 0, group: 0, bit: 4 });
        b.record(&FsmState::Done);
        assert_eq!(b.load, 1);
        assert_eq!(b.compute, 2);
        assert_eq!(b.total(), 4);
        // Idle cycles are not counted toward inference latency
        b.record(&FsmState::Idle);
        assert_eq!(b.total(), 4);
    }

    #[test]
    fn stage_names() {
        assert_eq!(FsmState::Idle.stage(), "idle");
        assert_eq!(FsmState::Argmax { step: 3 }.stage(), "argmax");
    }
}
