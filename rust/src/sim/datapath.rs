//! The P-parallel XNOR-popcount datapath (§3.3, §3.5).
//!
//! `P` neuron units run in lock-step: in each compute cycle the broadcast
//! input bit is XNOR'd with every active unit's private weight bit and the
//! unit's match counter increments on agreement.  At group writeback each
//! unit evaluates `z = 2·popcount − n` against its folded threshold
//! (hidden layers) or latches the raw sum (output layer) — Algorithm 1
//! lines 5–18 in hardware form.

/// One neuron unit's registers.
#[derive(Clone, Copy, Debug, Default)]
pub struct NeuronUnit {
    /// Matches counted so far (popcount of XNOR), Algorithm 1 line 10.
    pub popcount: u16,
    /// Global neuron index served this group (None ⇒ unit idle: last group
    /// of a layer may be partial, e.g. 10 output neurons on 64 units).
    pub neuron: Option<u16>,
}

/// The array of `P` units plus activity counters for the power model.
#[derive(Clone, Debug)]
pub struct Datapath {
    pub units: Vec<NeuronUnit>,
    /// Total XNOR evaluations (switching-activity proxy).
    pub xnor_ops: u64,
    /// Total popcount-register increments.
    pub counter_increments: u64,
    /// Threshold comparator evaluations.
    pub comparisons: u64,
}

impl Datapath {
    pub fn new(parallelism: usize) -> Self {
        Self {
            units: vec![NeuronUnit::default(); parallelism],
            xnor_ops: 0,
            counter_increments: 0,
            comparisons: 0,
        }
    }

    pub fn parallelism(&self) -> usize {
        self.units.len()
    }

    /// Assign the units to neuron group `group` of a layer with `n_out`
    /// neurons; resets the match counters.  Returns the active unit count.
    pub fn load_group(&mut self, group: usize, n_out: usize) -> usize {
        let base = group * self.units.len();
        let mut active = 0;
        for (k, u) in self.units.iter_mut().enumerate() {
            let j = base + k;
            if j < n_out {
                u.neuron = Some(j as u16);
                u.popcount = 0;
                active += 1;
            } else {
                u.neuron = None;
            }
        }
        active
    }

    /// One compute cycle: broadcast input bit, each active unit XNORs its
    /// own weight bit.  `weight_bit(j)` supplies neuron `j`'s bit for the
    /// current input index (from BRAM output registers or LUT-ROM).
    #[inline]
    pub fn compute_bit(&mut self, x_bit: u8, mut weight_bit: impl FnMut(usize) -> u8) {
        for u in self.units.iter_mut() {
            if let Some(j) = u.neuron {
                let w = weight_bit(j as usize);
                self.xnor_ops += 1;
                if w == x_bit {
                    u.popcount += 1; // XNOR = 1 on match (§2.1)
                    self.counter_increments += 1;
                }
            }
        }
    }

    /// Group writeback for a hidden layer: per active unit compute
    /// `z = 2m − n` and the threshold activation bit; `sink(j, bit)`
    /// receives the results.
    pub fn writeback_hidden(
        &mut self,
        n_in: usize,
        mut threshold: impl FnMut(usize) -> i32,
        mut sink: impl FnMut(usize, u8),
    ) {
        for u in self.units.iter() {
            if let Some(j) = u.neuron {
                let z = 2 * i32::from(u.popcount) - n_in as i32;
                self.comparisons += 1;
                sink(j as usize, u8::from(z >= threshold(j as usize)));
            }
        }
    }

    /// Group writeback for the output layer: latch raw sums (§3.4 "no
    /// thresholding is applied").
    pub fn writeback_output(&mut self, n_in: usize, mut sink: impl FnMut(usize, i32)) {
        for u in self.units.iter() {
            if let Some(j) = u.neuron {
                sink(j as usize, 2 * i32::from(u.popcount) - n_in as i32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_group_deactivates_units() {
        let mut dp = Datapath::new(64);
        assert_eq!(dp.load_group(0, 10), 10);
        assert_eq!(dp.units.iter().filter(|u| u.neuron.is_some()).count(), 10);
        assert_eq!(dp.units[9].neuron, Some(9));
        assert_eq!(dp.units[10].neuron, None);
    }

    #[test]
    fn second_group_indexes_continue() {
        let mut dp = Datapath::new(64);
        assert_eq!(dp.load_group(1, 128), 64);
        assert_eq!(dp.units[0].neuron, Some(64));
        assert_eq!(dp.units[63].neuron, Some(127));
    }

    #[test]
    fn xnor_popcount_semantics() {
        let mut dp = Datapath::new(2);
        dp.load_group(0, 2);
        // neuron 0 weight bits: [1, 0]; neuron 1: [1, 1]; input [1, 0]
        let w = [[1u8, 0], [1, 1]];
        dp.compute_bit(1, |j| w[j][0]);
        dp.compute_bit(0, |j| w[j][1]);
        // n0 matches both bits → popcount 2; n1 matches first only → 1
        assert_eq!(dp.units[0].popcount, 2);
        assert_eq!(dp.units[1].popcount, 1);
        assert_eq!(dp.xnor_ops, 4);
        assert_eq!(dp.counter_increments, 3);

        // z = 2m − n: n0 → 2, n1 → 0; threshold 1 → n0 fires, n1 doesn't
        let mut bits = [9u8; 2];
        dp.writeback_hidden(2, |_| 1, |j, b| bits[j] = b);
        assert_eq!(bits, [1, 0]);
        assert_eq!(dp.comparisons, 2);
    }

    #[test]
    fn output_writeback_raw_sums() {
        let mut dp = Datapath::new(4);
        dp.load_group(0, 3);
        dp.units[0].popcount = 64; // all 64 inputs matched
        dp.units[1].popcount = 0;
        dp.units[2].popcount = 32;
        let mut scores = [0i32; 3];
        dp.writeback_output(64, |j, z| scores[j] = z);
        assert_eq!(scores, [64, -64, 0]);
    }
}
