//! Distributed LUT-ROM model (§3.3: "LUT-based ROMs are used for thresholds
//! to minimize BRAM usage"; also the weight store in the LUT memory style).
//!
//! Combinational (same-cycle) reads; LUT-cost accounting: a depth-`d`
//! single-bit ROM costs `ceil(d/64)` LUT6s (64×1 ROM per LUT6, wider
//! depths via F7/F8 muxes folded into the same estimate), so a
//! `width × depth` ROM costs `width · ceil(depth/64)` LUTs.

/// LUTs required for a `width × depth` distributed ROM.
pub fn luts_for(width_bits: usize, depth: usize) -> usize {
    width_bits * depth.div_ceil(64)
}

/// A combinational ROM holding packed rows (weights) or signed words
/// (thresholds / generic data).
#[derive(Clone, Debug)]
pub struct LutRom<T: Copy> {
    pub data: Vec<T>,
    pub reads: std::cell::Cell<u64>,
}

impl<T: Copy> LutRom<T> {
    pub fn new(data: Vec<T>) -> Self {
        Self {
            data,
            reads: std::cell::Cell::new(0),
        }
    }

    /// Combinational read — available in the same cycle.
    #[inline]
    pub fn read(&self, addr: usize) -> T {
        self.reads.set(self.reads.get() + 1);
        self.data[addr]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Packed-row LUT-ROM for weights in the LUT memory style.
#[derive(Clone, Debug)]
pub struct LutWeightRom {
    pub width_bits: usize,
    pub depth: usize,
    words_per_row: usize,
    data: Vec<u64>,
    pub reads: u64,
    pub read_bits: u64,
}

impl LutWeightRom {
    pub fn new(width_bits: usize, rows: &[&[u64]]) -> Self {
        let words_per_row = width_bits.div_ceil(64);
        let mut data = Vec::with_capacity(rows.len() * words_per_row);
        for r in rows {
            assert_eq!(r.len(), words_per_row);
            data.extend_from_slice(r);
        }
        Self {
            width_bits,
            depth: rows.len(),
            words_per_row,
            data,
            reads: 0,
            read_bits: 0,
        }
    }

    pub fn luts(&self) -> usize {
        luts_for(self.width_bits, self.depth)
    }

    /// Combinational row access (no clock needed — this is the 10 ns the
    /// LUT style saves on the initial image-row load).
    pub fn row_words(&mut self, row: usize) -> &[u64] {
        self.reads += 1;
        self.read_bits += self.width_bits as u64;
        &self.data[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    #[inline]
    pub fn bit(&self, row: usize, bit: usize) -> u8 {
        ((self.data[row * self.words_per_row + bit / 64] >> (bit % 64)) & 1) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_costs() {
        assert_eq!(luts_for(1, 64), 1);
        assert_eq!(luts_for(1, 65), 2);
        assert_eq!(luts_for(784, 128), 784 * 2);
        // thresholds: 11-bit × 128 deep → 11·2 = 22 LUTs
        assert_eq!(luts_for(11, 128), 22);
    }

    #[test]
    fn combinational_read_counts() {
        let rom = LutRom::new(vec![5i32, -3, 7]);
        assert_eq!(rom.read(1), -3);
        assert_eq!(rom.read(2), 7);
        assert_eq!(rom.reads.get(), 2);
    }

    #[test]
    fn weight_rom_bits() {
        let rows: Vec<Vec<u64>> = vec![vec![0b110]];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rom = LutWeightRom::new(3, &refs);
        assert_eq!(rom.bit(0, 0), 0);
        assert_eq!(rom.bit(0, 1), 1);
        assert_eq!(rom.row_words(0), &[0b110]);
        assert_eq!(rom.reads, 1);
    }
}
