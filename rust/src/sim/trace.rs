//! VCD waveform tracing for the accelerator — the paper validates its
//! Verilog "through module-level testing and waveform inspection" (§5);
//! this module gives the simulator the same affordance.  Output is
//! standard IEEE-1364 VCD, loadable in GTKWave.
//!
//! Traced signals: FSM stage (3-bit enum), layer/group/bit counters, the
//! active-unit count, the argmax best index, and the seven-segment bus.

use std::fmt::Write as _;

use super::fsm::FsmState;

/// One VCD signal definition.
struct Signal {
    id: char,
    name: &'static str,
    width: u8,
    last: Option<u64>,
}

/// A VCD trace builder; feed it one sample per cycle.
pub struct VcdTrace {
    signals: Vec<Signal>,
    body: String,
    time: u64,
    /// ns per cycle, recorded in the timescale header.
    step_ns: f64,
}

/// Stage encoding for the `fsm_stage` signal.
pub fn stage_code(s: &FsmState) -> u64 {
    match s {
        FsmState::Idle => 0,
        FsmState::LoadImage { .. } => 1,
        FsmState::LayerPrologue { .. } => 2,
        FsmState::GroupLoad { .. } => 3,
        FsmState::ComputeBit { .. } => 4,
        FsmState::GroupWriteback { .. } => 5,
        FsmState::Argmax { .. } => 6,
        FsmState::Done => 7,
    }
}

/// Per-cycle sample of the architectural signals.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sample {
    pub stage: u64,
    pub layer: u64,
    pub group: u64,
    pub bit: u64,
    pub active_units: u64,
    pub best_idx: u64,
    pub sevenseg: u64,
}

impl VcdTrace {
    pub fn new(step_ns: f64) -> Self {
        let mk = |id, name, width| Signal {
            id,
            name,
            width,
            last: None,
        };
        VcdTrace {
            signals: vec![
                mk('a', "fsm_stage", 3),
                mk('b', "layer", 2),
                mk('c', "group", 8),
                mk('d', "bit_index", 10),
                mk('e', "active_units", 8),
                mk('f', "argmax_best", 4),
                mk('g', "sevenseg_n", 7),
            ],
            body: String::new(),
            time: 0,
            step_ns,
        }
    }

    /// Record one cycle's sample (only changed signals are emitted).
    pub fn tick(&mut self, s: &Sample) {
        let values = [
            s.stage,
            s.layer,
            s.group,
            s.bit,
            s.active_units,
            s.best_idx,
            s.sevenseg,
        ];
        let mut wrote_time = false;
        for (sig, &v) in self.signals.iter_mut().zip(values.iter()) {
            if sig.last != Some(v) {
                if !wrote_time {
                    let _ = writeln!(self.body, "#{}", self.time);
                    wrote_time = true;
                }
                if sig.width == 1 {
                    let _ = writeln!(self.body, "{}{}", v & 1, sig.id);
                } else {
                    let _ = writeln!(self.body, "b{:b} {}", v, sig.id);
                }
                sig.last = Some(v);
            }
        }
        self.time += 1;
    }

    pub fn cycles(&self) -> u64 {
        self.time
    }

    /// Render the complete VCD document.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "$date bnn-fpga simulator trace $end");
        let _ = writeln!(out, "$version bnn-fpga 0.1.0 $end");
        // VCD wants integer timescales; 10 ns/step → 10ns, 12.5 → 500ps×25… keep ns.
        let _ = writeln!(out, "$timescale {}ns $end", self.step_ns.round() as u64);
        let _ = writeln!(out, "$scope module accelerator $end");
        for s in &self.signals {
            let _ = writeln!(out, "$var wire {} {} {} $end", s.width, s.id, s.name);
        }
        let _ = writeln!(out, "$upscope $end");
        let _ = writeln!(out, "$enddefinitions $end");
        out.push_str(&self.body);
        let _ = writeln!(out, "#{}", self.time);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_codes_are_distinct() {
        let states = [
            FsmState::Idle,
            FsmState::LoadImage { substep: 0 },
            FsmState::LayerPrologue { layer: 0 },
            FsmState::GroupLoad { layer: 0, group: 0 },
            FsmState::ComputeBit { layer: 0, group: 0, bit: 0 },
            FsmState::GroupWriteback { layer: 0, group: 0 },
            FsmState::Argmax { step: 0 },
            FsmState::Done,
        ];
        let codes: std::collections::HashSet<u64> = states.iter().map(stage_code).collect();
        assert_eq!(codes.len(), states.len());
        assert!(codes.iter().all(|&c| c < 8), "3-bit encoding");
    }

    #[test]
    fn vcd_structure_and_change_compression() {
        let mut t = VcdTrace::new(10.0);
        let mut s = Sample::default();
        t.tick(&s); // all signals emitted at #0
        t.tick(&s); // no change → nothing emitted at #1
        s.stage = 4;
        s.bit = 3;
        t.tick(&s); // two changes at #2
        let vcd = t.render();
        assert!(vcd.contains("$timescale 10ns $end"));
        assert!(vcd.contains("$var wire 3 a fsm_stage $end"));
        assert!(vcd.contains("#0\n"));
        assert!(!vcd.contains("#1\n"), "unchanged cycle must be elided");
        assert!(vcd.contains("#2\nb100 a\nb11 d"));
        assert_eq!(t.cycles(), 3);
    }
}
