//! Cycle-accurate simulator of the paper's Verilog accelerator.
//!
//! The design under simulation (paper §3.3–§3.5): a centralized FSM drives
//! `P` parallel XNOR-popcount neuron units through the three fully-connected
//! layers; binary weights live in dual-port BRAM (or LUT-ROM), folded
//! batch-norm thresholds in LUT-ROM; the output layer keeps raw sums and an
//! iterative comparator picks the argmax, latched to a seven-segment
//! decoder.
//!
//! ## Microarchitecture (reverse-engineered from Table 1)
//!
//! The paper does not publish its RTL inner loop, but its latency table
//! pins it down: with `S(P) = Σ_l ⌈N_l/P⌉·I_l` (input bits streamed per
//! neuron group) and `G(P) = Σ_l ⌈N_l/P⌉` (groups), every BRAM row of
//! Table 1 satisfies
//!
//! ```text
//!   latency_ns = 10·S(P) + 20·G(P) + 165   (±5 ns)
//! ```
//!
//! and every LUT row is exactly 10 ns less (one fewer read-latency cycle).
//! This simulator therefore executes: 1 cycle per broadcast input bit per
//! group (each of the ≤P units XNORs its private weight bit and bumps its
//! popcount), 2 cycles per group (weight-row latch + threshold/writeback),
//! 1 cycle per layer prologue, 10 argmax cycles, load + done — totalling
//! `S + 2G + 15 (+1 BRAM read-latency)` cycles, reproducing the table.
//!
//! **Clock note**: the per-step time implied by the paper's own numbers is
//! 10 ns, although §3.5 states an 80 MHz (12.5 ns) clock — the published
//! latencies are internally consistent only at 10 ns/step.  We default to
//! the table-calibrated 10 ns step ([`SimConfig::step_ns`]) and expose the
//! strict 12.5 ns mode; EXPERIMENTS.md discusses the discrepancy.

pub mod bram;
pub mod datapath;
pub mod fsm;
pub mod lutrom;
pub mod sevenseg;
pub mod top;
pub mod trace;

pub use fsm::FsmState;
pub use top::{Accelerator, InferenceResult};

/// Weight-memory style of Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemStyle {
    /// Dual-port block RAM rows (one neuron's weights per row).
    Bram,
    /// Distributed LUT-ROM synthesized into the fabric.
    Lut,
}

impl MemStyle {
    pub fn name(self) -> &'static str {
        match self {
            MemStyle::Bram => "BRAM",
            MemStyle::Lut => "LUT",
        }
    }
}

/// Simulator configuration (the paper's two sweep axes + clock model).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Neurons processed in parallel (paper sweeps 1..=128).
    pub parallelism: usize,
    pub mem_style: MemStyle,
    /// Nanoseconds per FSM step.  Default 10.0 — the value the paper's own
    /// Table 1 implies (see module docs); 12.5 is the strict-80 MHz mode.
    pub step_ns: f64,
}

impl SimConfig {
    pub fn new(parallelism: usize, mem_style: MemStyle) -> Self {
        assert!(
            (1..=128).contains(&parallelism),
            "parallelism {parallelism} outside the paper's 1..=128 range"
        );
        Self {
            parallelism,
            mem_style,
            step_ns: 10.0,
        }
    }

    pub fn strict_80mhz(mut self) -> Self {
        self.step_ns = 12.5;
        self
    }

    /// The 13 (parallelism, style) rows of Table 1, in paper order.
    pub fn table1_rows() -> Vec<SimConfig> {
        let mut rows = Vec::new();
        for p in [1usize, 4, 8, 16, 32, 64] {
            rows.push(SimConfig::new(p, MemStyle::Bram));
            rows.push(SimConfig::new(p, MemStyle::Lut));
        }
        // §4.2.1: BRAM fails to synthesize beyond 64; 128 is LUT-only.
        rows.push(SimConfig::new(128, MemStyle::Lut));
        rows
    }
}

/// Closed-form step count — the analytical counterpart the cycle loop is
/// asserted against in tests (`top::tests::formula_matches_execution`).
pub fn analytic_steps(dims: &[usize], parallelism: usize, mem_style: MemStyle) -> u64 {
    let mut s = 0u64; // bit-broadcast steps
    let mut g = 0u64; // neuron groups
    for w in dims.windows(2) {
        let groups = w[1].div_ceil(parallelism) as u64;
        g += groups;
        s += groups * w[0] as u64;
    }
    let layers = (dims.len() - 1) as u64;
    let argmax = *dims.last().unwrap() as u64;
    let load = match mem_style {
        MemStyle::Bram => 2, // input row read latency
        MemStyle::Lut => 1,
    };
    s + 2 * g + layers + argmax + load + 1 /* done */
}

/// Closed-form step count of the conv front alone: per conv layer, one
/// prologue plus the dense group/bit microloop re-run per output patch —
/// `n_patches · (groups·k²·C_in + 2·groups)` with
/// `groups = ⌈C_out/P⌉`.  0 for dense-only models; memory style does not
/// enter (the image-load latency is counted once, in
/// [`analytic_steps`]'s `load` term).
pub fn conv_front_steps(model: &crate::bnn::BnnModel, parallelism: usize) -> u64 {
    model
        .conv
        .iter()
        .map(|cl| {
            let groups = cl.out_ch().div_ceil(parallelism) as u64;
            let per_patch = groups * cl.patch_bits() as u64 + 2 * groups;
            1 + cl.n_patches() as u64 * per_patch
        })
        .sum()
}

/// Closed-form step count for a full (conv→dense) model — the
/// model-aware counterpart of [`analytic_steps`], asserted against the
/// cycle loop in `top::tests::conv_formula_matches_execution`.  Equals
/// `analytic_steps(&dims, …)` exactly when the model is dense-only, so
/// the Table-1 calibration is untouched.
pub fn analytic_steps_model(
    model: &crate::bnn::BnnModel,
    parallelism: usize,
    mem_style: MemStyle,
) -> u64 {
    let mut dims = vec![model.dense_n_in()];
    dims.extend(model.layers.iter().map(|l| l.n_out));
    conv_front_steps(model, parallelism) + analytic_steps(&dims, parallelism, mem_style)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_steps_match_paper_table1() {
        // Paper Table 1 latencies (ns) vs the model at 10 ns/step.
        let cases = [
            (1, MemStyle::Bram, 1_096_045.0),
            (1, MemStyle::Lut, 1_096_035.0),
            (4, MemStyle::Bram, 274_465.0),
            (4, MemStyle::Lut, 274_455.0),
            (8, MemStyle::Bram, 137_645.0),
            (8, MemStyle::Lut, 137_635.0),
            (16, MemStyle::Bram, 68_905.0),
            (16, MemStyle::Lut, 68_895.0),
            (32, MemStyle::Bram, 34_865.0),
            (32, MemStyle::Lut, 34_855.0),
            (64, MemStyle::Bram, 17_845.0),
            (64, MemStyle::Lut, 17_835.0),
            (128, MemStyle::Lut, 9_865.0),
        ];
        for (p, style, paper_ns) in cases {
            let steps = analytic_steps(&[784, 128, 64, 10], p, style);
            let ns = steps as f64 * 10.0;
            let err = (ns - paper_ns).abs() / paper_ns;
            // ≤0.1% everywhere except the paper's own P=128 outlier (≤1.2%)
            let tol = if p == 128 { 0.012 } else { 0.001 };
            assert!(
                err <= tol,
                "P={p} {style:?}: model {ns} vs paper {paper_ns} ({:.3}%)",
                err * 100.0
            );
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn parallelism_range_checked() {
        SimConfig::new(0, MemStyle::Bram);
    }

    #[test]
    fn model_steps_reduce_to_dense_formula_without_conv() {
        let model = crate::bnn::random_model(&[784, 128, 64, 10], 3);
        for p in [1usize, 16, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                assert_eq!(
                    analytic_steps_model(&model, p, style),
                    analytic_steps(&[784, 128, 64, 10], p, style)
                );
            }
        }
        assert_eq!(conv_front_steps(&model, 16), 0);
    }

    #[test]
    fn conv_front_steps_closed_form() {
        // one conv layer: 8×8 pad 1 k3 s1 → 64 patches, 6 channels,
        // 9 patch bits; at P=4 → 2 groups
        let model = crate::bnn::random_conv_model((1, 8, 8), &[(6, 3, 1, 1)], &[24, 10], 5);
        let groups = 2u64;
        let expect = 1 + 64 * (groups * 9 + 2 * groups);
        assert_eq!(conv_front_steps(&model, 4), expect);
        assert_eq!(
            analytic_steps_model(&model, 4, MemStyle::Lut),
            expect + analytic_steps(&[6 * 8 * 8, 24, 10], 4, MemStyle::Lut)
        );
    }

    #[test]
    fn table1_rows_enumeration() {
        let rows = SimConfig::table1_rows();
        assert_eq!(rows.len(), 13);
        assert_eq!(rows.last().unwrap().parallelism, 128);
        assert_eq!(rows.last().unwrap().mem_style, MemStyle::Lut);
    }
}
