//! The top-level accelerator: memories + datapath + FSM + display, driven
//! one clock cycle per [`Accelerator::tick`].
//!
//! Faithfulness contracts (enforced by tests):
//! * predictions are bit-identical to the software [`crate::bnn::BnnModel`]
//!   (same weights ⇒ same digit, same logits);
//! * executed cycle counts equal [`super::analytic_steps`] — which in turn
//!   matches the paper's Table 1 latencies at 10 ns/step (see `sim` docs).

use anyhow::Result;

use super::bram::DualPortBram;
use super::datapath::Datapath;
use super::fsm::{CycleBreakdown, FsmState};
use super::lutrom::{LutRom, LutWeightRom};
use super::sevenseg;
use super::{MemStyle, SimConfig};
use crate::bnn::BnnModel;

/// Per-layer weight memory in the configured style.
enum WeightMem {
    Bram(DualPortBram),
    Lut(LutWeightRom),
}

impl WeightMem {
    #[inline]
    fn bit(&self, row: usize, bit: usize) -> u8 {
        match self {
            WeightMem::Bram(m) => m.bit(row, bit),
            WeightMem::Lut(m) => m.bit(row, bit),
        }
    }

    fn count_row_reads(&mut self, rows: u64) {
        match self {
            WeightMem::Bram(m) => {
                m.reads += rows;
                m.read_bits += rows * m.width_bits as u64;
            }
            WeightMem::Lut(m) => {
                m.reads += rows;
                m.read_bits += rows * m.width_bits as u64;
            }
        }
    }
}

struct LayerMem {
    n_in: usize,
    n_out: usize,
    weights: WeightMem,
    thresholds: Option<LutRom<i32>>,
}

/// Conv-front layer: the dense-core memories (`n_in = k²·C_in` patch
/// bits, `n_out = C_out`, thresholds mandatory) plus the spatial geometry
/// the window mux needs.  The datapath model re-runs the dense group/bit
/// microloop once per output patch — hardware would feed the broadcast
/// bit through a receptive-field mux instead of the activation register
/// file, everything else is the §3.3 engine unchanged.
struct ConvLayerMem {
    in_ch: usize,
    in_h: usize,
    in_w: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
    out_ch: usize,
    out_h: usize,
    out_w: usize,
    mem: LayerMem,
}

/// Memory-activity counters feeding the power model (`estimate::power`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Activity {
    pub bram_row_reads: u64,
    pub bram_bits_read: u64,
    pub lutrom_row_reads: u64,
    pub lutrom_bits_read: u64,
    pub threshold_reads: u64,
    pub xnor_ops: u64,
    pub counter_increments: u64,
    pub comparisons: u64,
}

/// Result of one simulated inference.
#[derive(Clone, Debug)]
pub struct InferenceResult {
    pub digit: u8,
    /// Raw output-layer sums (the FSM's score registers).
    pub scores: Vec<i32>,
    pub cycles: u64,
    pub latency_ns: f64,
    pub breakdown: CycleBreakdown,
    pub activity: Activity,
    /// Active-low seven-segment pattern latched at DONE.
    pub sevenseg: u8,
}

/// The simulated accelerator.
pub struct Accelerator {
    pub cfg: SimConfig,
    dims: Vec<usize>,
    conv: Vec<ConvLayerMem>,
    layers: Vec<LayerMem>,
    dp: Datapath,
    state: FsmState,
    breakdown: CycleBreakdown,
    cycles: u64,
    /// Image width the testbench must feed (conv models take the raw
    /// `C·H·W`-bit image, not the dense stack's input width).
    expected_bits: usize,
    /// Closed-form conv-front step count (0 for dense-only models).
    conv_steps: u64,
    // architectural registers
    act_bits: Vec<u8>,
    next_bits: Vec<u8>,
    scores: Vec<i32>,
    best_idx: u8,
    best_val: i32,
    display: u8,
}

impl Accelerator {
    /// Instantiate the design for `model` at the given configuration —
    /// the `generate`-loop parameterization of §3.5.
    pub fn new(model: &BnnModel, cfg: SimConfig) -> Result<Self> {
        model.validate()?;
        let build_mem = |n_in: usize, n_out: usize, rows: &[&[u64]], thr: Option<Vec<i32>>| {
            let weights = match cfg.mem_style {
                MemStyle::Bram => WeightMem::Bram(DualPortBram::new(n_in, rows)),
                MemStyle::Lut => WeightMem::Lut(LutWeightRom::new(n_in, rows)),
            };
            LayerMem {
                n_in,
                n_out,
                weights,
                thresholds: thr.map(LutRom::new),
            }
        };
        let conv = model
            .conv
            .iter()
            .map(|cl| {
                let l = &cl.core;
                let rows: Vec<&[u64]> = (0..l.n_out).map(|j| l.row(j)).collect();
                ConvLayerMem {
                    in_ch: cl.in_ch,
                    in_h: cl.in_h,
                    in_w: cl.in_w,
                    kernel: cl.kernel,
                    stride: cl.stride,
                    pad: cl.pad,
                    out_ch: cl.out_ch(),
                    out_h: cl.out_h(),
                    out_w: cl.out_w(),
                    mem: build_mem(l.n_in, l.n_out, &rows, l.thresholds.clone()),
                }
            })
            .collect();
        let mut dims = vec![model.dense_n_in()];
        dims.extend(model.layers.iter().map(|l| l.n_out));
        let layers = model
            .layers
            .iter()
            .map(|l| {
                let rows: Vec<&[u64]> = (0..l.n_out).map(|j| l.row(j)).collect();
                build_mem(l.n_in, l.n_out, &rows, l.thresholds.clone())
            })
            .collect();
        let max_width = dims.iter().copied().max().unwrap();
        Ok(Self {
            dp: Datapath::new(cfg.parallelism),
            dims: dims.clone(),
            conv,
            layers,
            state: FsmState::Idle,
            breakdown: CycleBreakdown::default(),
            cycles: 0,
            expected_bits: model.n_in(),
            conv_steps: super::conv_front_steps(model, cfg.parallelism),
            act_bits: vec![0; max_width],
            next_bits: vec![0; max_width],
            scores: vec![0; *dims.last().unwrap()],
            best_idx: 0,
            best_val: i32::MIN,
            cfg,
            display: 0x7F,
        })
    }

    pub fn state(&self) -> FsmState {
        self.state
    }

    fn groups(&self, layer: usize) -> usize {
        self.layers[layer].n_out.div_ceil(self.cfg.parallelism)
    }

    /// Advance exactly one clock cycle.
    pub fn tick(&mut self) {
        let state = self.state;
        if state != FsmState::Idle {
            self.cycles += 1;
            self.breakdown.record(&state);
        }
        self.state = match state {
            FsmState::Idle => FsmState::Idle,

            FsmState::LoadImage { substep } => {
                let needed = match self.cfg.mem_style {
                    MemStyle::Bram => 2, // synchronous image-ROM read latency
                    MemStyle::Lut => 1,
                };
                if substep + 1 < needed {
                    FsmState::LoadImage { substep: substep + 1 }
                } else {
                    FsmState::LayerPrologue { layer: 0 }
                }
            }

            FsmState::LayerPrologue { layer } => FsmState::GroupLoad { layer, group: 0 },

            FsmState::GroupLoad { layer, group } => {
                let l = &mut self.layers[layer as usize];
                let active = self.dp.load_group(group as usize, l.n_out);
                l.weights.count_row_reads(active as u64);
                FsmState::ComputeBit { layer, group, bit: 0 }
            }

            FsmState::ComputeBit { layer, group, bit } => {
                let l = &self.layers[layer as usize];
                let x_bit = self.act_bits[bit as usize];
                let weights = &l.weights;
                self.dp
                    .compute_bit(x_bit, |j| weights.bit(j, bit as usize));
                if (bit as usize) + 1 < l.n_in {
                    FsmState::ComputeBit { layer, group, bit: bit + 1 }
                } else {
                    FsmState::GroupWriteback { layer, group }
                }
            }

            FsmState::GroupWriteback { layer, group } => {
                let li = layer as usize;
                let is_output = li + 1 == self.layers.len();
                if is_output {
                    let n_in = self.layers[li].n_in;
                    let scores = &mut self.scores;
                    self.dp.writeback_output(n_in, |j, z| scores[j] = z);
                } else {
                    let n_in = self.layers[li].n_in;
                    let thr = self.layers[li].thresholds.as_ref().expect("hidden thresholds");
                    let next = &mut self.next_bits;
                    self.dp
                        .writeback_hidden(n_in, |j| thr.read(j), |j, b| next[j] = b);
                }
                if (group as usize) + 1 < self.groups(li) {
                    FsmState::GroupLoad { layer, group: group + 1 }
                } else if !is_output {
                    std::mem::swap(&mut self.act_bits, &mut self.next_bits);
                    FsmState::LayerPrologue { layer: layer + 1 }
                } else {
                    self.best_idx = 0;
                    self.best_val = i32::MIN;
                    FsmState::Argmax { step: 0 }
                }
            }

            FsmState::Argmax { step } => {
                // iterative comparison, strict > keeps the first maximum
                if self.scores[step as usize] > self.best_val {
                    self.best_val = self.scores[step as usize];
                    self.best_idx = step;
                }
                if (step as usize) + 1 < self.scores.len() {
                    FsmState::Argmax { step: step + 1 }
                } else {
                    self.display = sevenseg::decode(self.best_idx);
                    FsmState::Done
                }
            }

            FsmState::Done => FsmState::Done,
        };
    }

    /// Execute the conv front bit-serially through the shared datapath —
    /// the dense group/bit microloop re-run once per output patch (the
    /// window mux gathers each receptive field; padding bits read 0,
    /// i.e. −1).  Cycle/activity accounting mirrors the dense FSM states
    /// exactly: one prologue per conv layer, then per patch per group
    /// one GroupLoad + `patch_bits` ComputeBit + one Writeback — the
    /// closed form [`super::conv_front_steps`] is asserted in tests.
    fn run_conv_front(&mut self, bits: &[u8]) -> Vec<u8> {
        let mut cur = bits.to_vec();
        for ci in 0..self.conv.len() {
            self.cycles += 1;
            self.breakdown.prologue += 1;
            let c = &self.conv[ci];
            let (in_ch, in_h, in_w) = (c.in_ch, c.in_h, c.in_w);
            let (k, stride, pad) = (c.kernel, c.stride, c.pad);
            let (out_ch, out_h, out_w) = (c.out_ch, c.out_h, c.out_w);
            let mut next = vec![0u8; out_ch * out_h * out_w];
            let mut patch = vec![0u8; k * k * in_ch];
            for oy in 0..out_h {
                for ox in 0..out_w {
                    patch.fill(0);
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            let src = (iy as usize * in_w + ix as usize) * in_ch;
                            let dst = (ky * k + kx) * in_ch;
                            patch[dst..dst + in_ch].copy_from_slice(&cur[src..src + in_ch]);
                        }
                    }
                    let pos = oy * out_w + ox;
                    for g in 0..out_ch.div_ceil(self.cfg.parallelism) {
                        let active = self.dp.load_group(g, out_ch);
                        self.conv[ci].mem.weights.count_row_reads(active as u64);
                        self.cycles += 1;
                        self.breakdown.group_load += 1;
                        let mem = &self.conv[ci].mem;
                        for (bit, &x) in patch.iter().enumerate() {
                            let weights = &mem.weights;
                            self.dp.compute_bit(x, |j| weights.bit(j, bit));
                        }
                        self.cycles += patch.len() as u64;
                        self.breakdown.compute += patch.len() as u64;
                        let thr = mem.thresholds.as_ref().expect("conv thresholds");
                        let next_out = &mut next;
                        self.dp.writeback_hidden(
                            mem.n_in,
                            |j| thr.read(j),
                            |j, b| next_out[pos * out_ch + j] = b,
                        );
                        self.cycles += 1;
                        self.breakdown.writeback += 1;
                    }
                }
            }
            cur = next;
        }
        cur
    }

    /// Run one full inference on a packed image (`n_in()` bits — the raw
    /// `C·H·W` image for conv models, 784 for the paper's MLP).
    pub fn run_image(&mut self, image: &crate::bnn::Packed) -> InferenceResult {
        assert_eq!(image.n_bits, self.expected_bits, "image width");
        // reset architectural state (paper: result held until reset)
        self.cycles = 0;
        self.breakdown = CycleBreakdown::default();
        self.dp = Datapath::new(self.cfg.parallelism);
        for l in self.conv.iter_mut().map(|c| &mut c.mem).chain(self.layers.iter_mut()) {
            match &mut l.weights {
                WeightMem::Bram(m) => {
                    m.reads = 0;
                    m.read_bits = 0;
                }
                WeightMem::Lut(m) => {
                    m.reads = 0;
                    m.read_bits = 0;
                }
            }
            if let Some(t) = &l.thresholds {
                t.reads.set(0);
            }
        }
        let bits = image.to_bits();
        let dense_bits = if self.conv.is_empty() {
            bits
        } else {
            self.run_conv_front(&bits)
        };
        self.act_bits[..dense_bits.len()].copy_from_slice(&dense_bits);
        self.state = FsmState::LoadImage { substep: 0 };

        let budget = self.conv_steps
            + super::analytic_steps(&self.dims, self.cfg.parallelism, self.cfg.mem_style);
        while self.state != FsmState::Done {
            self.tick();
            assert!(
                self.cycles <= budget + 8,
                "FSM exceeded analytic cycle budget ({budget})"
            );
        }
        self.tick(); // the DONE cycle itself (result latch)

        let mut activity = Activity {
            xnor_ops: self.dp.xnor_ops,
            counter_increments: self.dp.counter_increments,
            comparisons: self.dp.comparisons,
            ..Default::default()
        };
        for l in self.conv.iter().map(|c| &c.mem).chain(self.layers.iter()) {
            match &l.weights {
                WeightMem::Bram(m) => {
                    activity.bram_row_reads += m.reads;
                    activity.bram_bits_read += m.read_bits;
                }
                WeightMem::Lut(m) => {
                    activity.lutrom_row_reads += m.reads;
                    activity.lutrom_bits_read += m.read_bits;
                }
            }
            if let Some(t) = &l.thresholds {
                activity.threshold_reads += t.reads.get();
            }
        }

        InferenceResult {
            digit: self.best_idx,
            scores: self.scores.clone(),
            cycles: self.cycles,
            latency_ns: self.cycles as f64 * self.cfg.step_ns,
            breakdown: self.breakdown.clone(),
            activity,
            sevenseg: self.display,
        }
    }

    /// Convenience: run a batch sequentially (the hardware is single-image).
    pub fn run_batch(&mut self, images: &[crate::bnn::Packed]) -> Vec<InferenceResult> {
        images.iter().map(|img| self.run_image(img)).collect()
    }

    /// Run one inference while recording a VCD waveform of the
    /// architectural signals (§5 "waveform inspection" affordance).
    pub fn run_image_traced(
        &mut self,
        image: &crate::bnn::Packed,
    ) -> (InferenceResult, super::trace::VcdTrace) {
        use super::trace::VcdTrace;
        // reset exactly as run_image does
        let first = self.run_image(image); // establishes deterministic state
        let mut trace = VcdTrace::new(self.cfg.step_ns);
        let bits = image.to_bits();
        let dense_bits = if self.conv.is_empty() {
            bits
        } else {
            self.run_conv_front(&bits) // trace covers the dense FSM only
        };
        self.act_bits[..dense_bits.len()].copy_from_slice(&dense_bits);
        self.cycles = 0;
        self.breakdown = CycleBreakdown::default();
        self.state = FsmState::LoadImage { substep: 0 };
        while self.state != FsmState::Done {
            trace.tick(&self.sample());
            self.tick();
        }
        trace.tick(&self.sample()); // the DONE cycle
        self.tick();
        (first, trace)
    }

    fn sample(&self) -> super::trace::Sample {
        use super::trace::{stage_code, Sample};
        let (layer, group, bit) = match self.state {
            FsmState::LayerPrologue { layer } => (layer as u64, 0, 0),
            FsmState::GroupLoad { layer, group } => (layer as u64, group as u64, 0),
            FsmState::ComputeBit { layer, group, bit } => {
                (layer as u64, group as u64, bit as u64)
            }
            FsmState::GroupWriteback { layer, group } => (layer as u64, group as u64, 0),
            _ => (0, 0, 0),
        };
        Sample {
            stage: stage_code(&self.state),
            layer,
            group,
            bit,
            active_units: self
                .dp
                .units
                .iter()
                .filter(|u| u.neuron.is_some())
                .count() as u64,
            best_idx: self.best_idx as u64,
            sevenseg: self.display as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::util::prng::Xoshiro256;

    fn random_model(seed: u64) -> BnnModel {
        let mut rng = Xoshiro256::new(seed);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            let thr = (li + 2 < dims.len()).then(|| {
                (0..w[1])
                    .map(|_| rng.range_i64(-(w[0] as i64) / 2, w[0] as i64 / 2) as i32)
                    .collect()
            });
            spec.push((rows, thr));
        }
        model_from_sign_rows(spec).unwrap()
    }

    fn random_image(rng: &mut Xoshiro256) -> crate::bnn::Packed {
        let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
        crate::bnn::Packed {
            words: pack_bits_u64(&bits),
            n_bits: 784,
        }
    }

    #[test]
    fn sim_matches_software_model() {
        let model = random_model(1);
        let mut rng = Xoshiro256::new(2);
        for &p in &[1usize, 4, 64, 128] {
            let mut acc = Accelerator::new(&model, SimConfig::new(p, MemStyle::Bram)).unwrap();
            for _ in 0..3 {
                let img = random_image(&mut rng);
                let r = acc.run_image(&img);
                assert_eq!(r.scores, model.logits(&img.words), "P={p} scores");
                assert_eq!(r.digit as usize, model.predict(&img.words), "P={p} digit");
                assert_eq!(r.sevenseg, sevenseg::decode(r.digit));
            }
        }
    }

    #[test]
    fn formula_matches_execution() {
        let model = random_model(3);
        let mut rng = Xoshiro256::new(4);
        let img = random_image(&mut rng);
        for cfg in SimConfig::table1_rows() {
            let mut acc = Accelerator::new(&model, cfg).unwrap();
            let r = acc.run_image(&img);
            let expect = super::super::analytic_steps(&[784, 128, 64, 10], cfg.parallelism, cfg.mem_style);
            assert_eq!(
                r.cycles, expect,
                "P={} {:?}",
                cfg.parallelism, cfg.mem_style
            );
            assert_eq!(r.breakdown.total(), r.cycles);
            assert_eq!(r.breakdown.argmax, 10);
        }
    }

    #[test]
    fn memory_styles_agree_on_results() {
        let model = random_model(5);
        let mut rng = Xoshiro256::new(6);
        let img = random_image(&mut rng);
        let mut a = Accelerator::new(&model, SimConfig::new(16, MemStyle::Bram)).unwrap();
        let mut b = Accelerator::new(&model, SimConfig::new(16, MemStyle::Lut)).unwrap();
        let ra = a.run_image(&img);
        let rb = b.run_image(&img);
        assert_eq!(ra.digit, rb.digit);
        assert_eq!(ra.scores, rb.scores);
        assert_eq!(ra.cycles, rb.cycles + 1, "BRAM pays 1 extra load cycle");
    }

    #[test]
    fn activity_accounting() {
        let model = random_model(7);
        let mut rng = Xoshiro256::new(8);
        let img = random_image(&mut rng);
        let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let r = acc.run_image(&img);
        // every neuron's row is read exactly once per inference
        assert_eq!(r.activity.bram_row_reads, 128 + 64 + 10);
        assert_eq!(
            r.activity.bram_bits_read,
            128 * 784 + 64 * 128 + 10 * 64
        );
        // every (neuron, input-bit) pair is one XNOR
        assert_eq!(r.activity.xnor_ops, 128 * 784 + 64 * 128 + 10 * 64);
        assert_eq!(r.activity.threshold_reads, 128 + 64);
        assert_eq!(r.activity.lutrom_bits_read, 0);
    }

    #[test]
    fn repeat_runs_are_stable() {
        let model = random_model(9);
        let mut rng = Xoshiro256::new(10);
        let img = random_image(&mut rng);
        let mut acc = Accelerator::new(&model, SimConfig::new(32, MemStyle::Lut)).unwrap();
        let r1 = acc.run_image(&img);
        let r2 = acc.run_image(&img);
        assert_eq!(r1.digit, r2.digit);
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(r1.activity, r2.activity);
    }

    fn random_packed(rng: &mut Xoshiro256, n_bits: usize) -> crate::bnn::Packed {
        let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
        crate::bnn::Packed {
            words: pack_bits_u64(&bits),
            n_bits,
        }
    }

    #[test]
    fn conv_sim_matches_software_model() {
        use crate::bnn::conv::random_conv_model;
        let specs = [
            random_conv_model((1, 10, 10), &[(6, 3, 1, 1)], &[32, 10], 11),
            random_conv_model((3, 9, 9), &[(5, 3, 1, 1), (7, 3, 2, 0)], &[33, 10], 12),
        ];
        let mut rng = Xoshiro256::new(13);
        for model in &specs {
            for &p in &[1usize, 16, 64] {
                let mut acc = Accelerator::new(model, SimConfig::new(p, MemStyle::Bram)).unwrap();
                for _ in 0..2 {
                    let img = random_packed(&mut rng, model.n_in());
                    let r = acc.run_image(&img);
                    assert_eq!(r.scores, model.logits(&img.words), "P={p} scores");
                    assert_eq!(r.digit as usize, model.predict(&img.words), "P={p} digit");
                }
            }
        }
    }

    #[test]
    fn conv_formula_matches_execution() {
        use crate::bnn::conv::random_conv_model;
        let model = random_conv_model((1, 8, 8), &[(6, 3, 1, 1)], &[24, 10], 14);
        let mut rng = Xoshiro256::new(15);
        let img = random_packed(&mut rng, model.n_in());
        for &p in &[1usize, 4, 64] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                let mut acc = Accelerator::new(&model, SimConfig::new(p, style)).unwrap();
                let r = acc.run_image(&img);
                let expect = super::super::analytic_steps_model(&model, p, style);
                assert_eq!(r.cycles, expect, "P={p} {style:?}");
                assert_eq!(r.breakdown.total(), r.cycles);
            }
        }
    }

    #[test]
    fn conv_activity_accounting() {
        use crate::bnn::conv::random_conv_model;
        // 6 channels of 3×3×1 patches over 8×8 pad 1 → 64 patches
        let model = random_conv_model((1, 8, 8), &[(6, 3, 1, 1)], &[24, 10], 16);
        let mut rng = Xoshiro256::new(17);
        let img = random_packed(&mut rng, model.n_in());
        let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let r = acc.run_image(&img);
        let (patches, oc, pb) = (64u64, 6u64, 9u64);
        let dense_in = 6 * 8 * 8;
        // conv: every channel row is re-read once per patch; dense: once
        assert_eq!(r.activity.bram_row_reads, patches * oc + 24 + 10);
        assert_eq!(
            r.activity.xnor_ops,
            patches * oc * pb + 24 * dense_in + 10 * 24
        );
        // conv thresholds read per (patch, channel); dense per hidden neuron
        assert_eq!(r.activity.threshold_reads, patches * oc + 24);
    }
}
