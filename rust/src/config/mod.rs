//! Configuration system: a TOML-subset parser + the typed serving config.
//!
//! Supported grammar (sufficient for deployment configs; full TOML is out
//! of scope offline): `[section]` headers, `key = value` with string,
//! integer, float and boolean values, `#` comments.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatcherConfig, Kernel, WireServerConfig, DEFAULT_QUEUE_CAP};
use crate::sim::MemStyle;

/// A parsed TOML-subset document: section → key → raw value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let key = k.trim().to_string();
            let value = Self::parse_value(v.trim())
                .with_context(|| format!("line {}: bad value for '{key}'", ln + 1))?;
            doc.sections.get_mut(&section).unwrap().insert(key, value);
        }
        Ok(doc)
    }

    fn parse_value(v: &str) -> Result<Value> {
        if let Some(s) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Ok(Value::Str(s.to_string()));
        }
        match v {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = v.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = v.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("unparseable value '{v}'")
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Section names that start with `prefix.` — e.g. `sections_under("models")`
    /// yields `("a", ..)` and `("b", ..)` for `[models.a]` / `[models.b]`,
    /// in document-independent sorted order.  The suffix is the part after
    /// the dot; full section names are reconstructible as `{prefix}.{suffix}`.
    pub fn sections_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.sections
            .keys()
            .filter_map(move |name| name.strip_prefix(prefix).and_then(|r| r.strip_prefix('.')))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(other) => bail!("[{section}] {key}: expected string, got {other:?}"),
        }
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Int(i)) => Ok(*i),
            Some(other) => bail!("[{section}] {key}: expected integer, got {other:?}"),
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(other) => bail!("[{section}] {key}: expected bool, got {other:?}"),
        }
    }
}

/// One `[models.NAME]` entry: a named engine for the multi-model registry
/// (`coordinator::ModelRegistry`).  With no `[models.*]` sections the serve
/// path stays single-model, exactly as before.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Registry name — what wire-v2 `FEAT_MODEL` sections route on.
    pub name: String,
    /// `weights.json` to load (`mem::load_model` format); absent means a
    /// seeded random 784→10 model (demo/smoke configs).
    pub weights: Option<std::path::PathBuf>,
    /// Per-model admission cap: at most this many requests in flight
    /// (`ModelRegistry::register_with_quota`); absent means uncapped.
    pub quota: Option<usize>,
    /// Route nameless requests here.  At most one entry may set this; with
    /// none set the first section (sorted order) is the default.
    pub default: bool,
}

/// Typed serving configuration (`bnn-fpga serve --config <file>`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Backends to register: any of "native", "pjrt", "fpga-sim".
    pub backends: Vec<String>,
    /// Worker threads; for native backends each worker owns a model replica
    /// (the sharded engine core, `coordinator::pool::WorkerPool`).
    pub workers: usize,
    /// Rows per pass of the blocked/tiled XNOR kernel (≥ 1); the software
    /// counterpart of the FPGA parallelism knob.
    pub block_rows: usize,
    /// Images per weight-stationary tile of the batch kernel (≥ 1) —
    /// `[coordinator] tile_imgs` / `--tile-imgs`.
    pub tile_imgs: usize,
    /// In-flight images per inter-stage ring of the pipelined kernel
    /// (≥ 1) — `[coordinator] ring_cap` / `--ring-cap`.
    pub ring_cap: usize,
    /// Native kernel tier, parsed from `[coordinator] kernel`
    /// (`scalar|blocked|tiled|simd|fused|pipelined`) and shaped by
    /// `block_rows`/`tile_imgs`/`ring_cap` at load time — a typo fails
    /// the config, and downstream code never re-parses a string.  `simd`
    /// and `fused` runtime-dispatch to AVX2/NEON and fall back to their
    /// portable kernels on hosts without them (or under
    /// `BNN_FORCE_SCALAR=1`); `fused` and `pipelined` additionally have
    /// their panel weights prepared once at engine build.
    pub kernel: Kernel,
    /// Backpressure bound (`[coordinator] queue_cap` / `--queue-cap`):
    /// submits fail once this many requests are queued (per shard on the
    /// sharded engine core).
    pub queue_cap: usize,
    pub batcher: BatcherConfig,
    /// Wire-server connection policy (`[server] max_conns` /
    /// `idle_timeout_ms`): the admission cap and the mid-frame stall bound
    /// both servers enforce (DESIGN.md §Async serving).
    pub server: WireServerConfig,
    /// Serve through the readiness-polled event loop
    /// ([`crate::coordinator::AsyncWireServer`]) instead of
    /// thread-per-connection (`[server] async` / `--serve-async`).
    pub async_serve: bool,
    /// FPGA-sim backend parameters.
    pub parallelism: usize,
    pub mem_style: MemStyle,
    /// Named models from `[models.NAME]` sections; empty means the classic
    /// single-model serve path.
    pub models: Vec<ModelConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: std::path::PathBuf::from("artifacts"),
            backends: vec!["native".into()],
            workers: 2,
            block_rows: crate::bnn::DEFAULT_BLOCK_ROWS,
            tile_imgs: crate::bnn::DEFAULT_TILE_IMGS,
            ring_cap: crate::bnn::DEFAULT_RING_CAP,
            kernel: Kernel::default(),
            queue_cap: DEFAULT_QUEUE_CAP,
            batcher: BatcherConfig::default(),
            server: WireServerConfig::default(),
            async_serve: false,
            parallelism: 64,
            mem_style: MemStyle::Bram,
            models: Vec::new(),
        }
    }
}

impl ServeConfig {
    pub fn from_toml(doc: &Toml) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let backends_raw = doc.str_or("coordinator", "backends", "native")?;
        let backends: Vec<String> = backends_raw
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        for b in &backends {
            if !["native", "pjrt", "fpga-sim"].contains(&b.as_str()) {
                bail!("unknown backend '{b}'");
            }
        }
        let mem_style = match doc.str_or("fpga", "mem_style", "bram")?.as_str() {
            "bram" => MemStyle::Bram,
            "lut" => MemStyle::Lut,
            other => bail!("mem_style must be bram|lut, got '{other}'"),
        };
        let parallelism = doc.int_or("fpga", "parallelism", d.parallelism as i64)? as usize;
        if !(1..=128).contains(&parallelism) {
            bail!("parallelism must be in 1..=128");
        }
        // validate on the signed value BEFORE the usize cast: a negative
        // config entry must be rejected, not wrapped to a huge count
        let workers = doc.int_or("coordinator", "workers", d.workers as i64)?;
        if workers < 1 {
            bail!("workers must be ≥ 1");
        }
        let workers = workers as usize;
        let block_rows = doc.int_or("coordinator", "block_rows", d.block_rows as i64)?;
        if block_rows < 1 {
            bail!("block_rows must be ≥ 1");
        }
        let block_rows = block_rows as usize;
        let tile_imgs = doc.int_or("coordinator", "tile_imgs", d.tile_imgs as i64)?;
        if tile_imgs < 1 {
            bail!("tile_imgs must be ≥ 1");
        }
        let tile_imgs = tile_imgs as usize;
        let ring_cap = doc.int_or("coordinator", "ring_cap", d.ring_cap as i64)?;
        if ring_cap < 1 {
            bail!("ring_cap must be ≥ 1");
        }
        let ring_cap = ring_cap as usize;
        // parse into the typed Kernel at load time so a typo fails the
        // config, not the first serve request, and so every consumer gets
        // the enum (the shape knobs are validated above)
        let kernel_name = doc.str_or("coordinator", "kernel", d.kernel.name())?;
        let kernel = Kernel::parse(&kernel_name, block_rows, tile_imgs)?.with_ring_cap(ring_cap);
        let queue_cap = doc.int_or("coordinator", "queue_cap", d.queue_cap as i64)?;
        if queue_cap < 1 {
            bail!("queue_cap must be ≥ 1");
        }
        let queue_cap = queue_cap as usize;
        let max_conns = doc.int_or("server", "max_conns", d.server.max_conns as i64)?;
        if max_conns < 1 {
            bail!("max_conns must be ≥ 1");
        }
        let idle_timeout_ms =
            doc.int_or("server", "idle_timeout_ms", d.server.idle_timeout.as_millis() as i64)?;
        if idle_timeout_ms < 1 {
            bail!("idle_timeout_ms must be ≥ 1");
        }
        let server = WireServerConfig {
            max_conns: max_conns as usize,
            idle_timeout: Duration::from_millis(idle_timeout_ms as u64),
        };
        let async_serve = doc.bool_or("server", "async", d.async_serve)?;
        let mut models = Vec::new();
        for name in doc.sections_under("models") {
            let section = format!("models.{name}");
            if name.is_empty() || name.len() > crate::coordinator::wire::MAX_MODEL_NAME {
                bail!(
                    "[{section}]: model name must be 1..={} bytes",
                    crate::coordinator::wire::MAX_MODEL_NAME
                );
            }
            let weights = match doc.str_or(&section, "weights", "")? {
                s if s.is_empty() => None,
                s => Some(std::path::PathBuf::from(s)),
            };
            let quota = match doc.int_or(&section, "quota", 0)? {
                0 => None,
                q if q < 0 => bail!("[{section}] quota: must be ≥ 1"),
                q => Some(q as usize),
            };
            let default = doc.bool_or(&section, "default", false)?;
            models.push(ModelConfig { name: name.to_string(), weights, quota, default });
        }
        if models.iter().filter(|m| m.default).count() > 1 {
            bail!("[models.*]: at most one model may set default = true");
        }
        Ok(ServeConfig {
            artifacts_dir: doc.str_or("coordinator", "artifacts_dir", "artifacts")?.into(),
            backends,
            workers,
            block_rows,
            tile_imgs,
            ring_cap,
            kernel,
            queue_cap,
            batcher: BatcherConfig {
                max_batch: doc.int_or("batcher", "max_batch", d.batcher.max_batch as i64)?
                    as usize,
                max_wait: Duration::from_micros(doc.int_or(
                    "batcher",
                    "max_wait_us",
                    d.batcher.max_wait.as_micros() as i64,
                )? as u64),
            },
            server,
            async_serve,
            parallelism,
            mem_style,
            models,
        })
    }

    pub fn load(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&Toml::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[coordinator]
backends = "native, fpga-sim"
workers = 4
block_rows = 32
tile_imgs = 8
ring_cap = 4
kernel = "simd"
queue_cap = 5000
artifacts_dir = "artifacts"

[batcher]
max_batch = 32
max_wait_us = 150

[server]
max_conns = 512
idle_timeout_ms = 30000
async = true

[fpga]
parallelism = 64
mem_style = "bram"
"#;

    #[test]
    fn parses_sample() {
        let cfg = ServeConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.backends, vec!["native", "fpga-sim"]);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.block_rows, 32);
        assert_eq!(cfg.tile_imgs, 8);
        // ring_cap is carried for the pipelined tier; with_ring_cap is a
        // no-op on every other tier, so "simd" is unaffected by it
        assert_eq!(cfg.ring_cap, 4);
        // the kernel arrives typed, already shaped by block_rows/tile_imgs
        assert_eq!(cfg.kernel, Kernel::Simd { block_rows: 32, tile_imgs: 8 });
        assert_eq!(cfg.queue_cap, 5000);
        assert_eq!(cfg.batcher.max_batch, 32);
        assert_eq!(cfg.batcher.max_wait, Duration::from_micros(150));
        assert_eq!(cfg.server.max_conns, 512);
        assert_eq!(cfg.server.idle_timeout, Duration::from_secs(30));
        assert!(cfg.async_serve);
        assert_eq!(cfg.parallelism, 64);
        assert_eq!(cfg.mem_style, MemStyle::Bram);
    }

    #[test]
    fn defaults_for_empty_doc() {
        let cfg = ServeConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.backends, vec!["native"]);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.block_rows, crate::bnn::DEFAULT_BLOCK_ROWS);
        assert_eq!(cfg.tile_imgs, crate::bnn::DEFAULT_TILE_IMGS);
        assert_eq!(cfg.ring_cap, crate::bnn::DEFAULT_RING_CAP);
        assert_eq!(cfg.kernel, Kernel::default());
        assert_eq!(cfg.queue_cap, DEFAULT_QUEUE_CAP);
        assert_eq!(cfg.server.max_conns, WireServerConfig::default().max_conns);
        assert_eq!(cfg.server.idle_timeout, WireServerConfig::default().idle_timeout);
        assert!(!cfg.async_serve);
    }

    #[test]
    fn every_registered_kernel_name_is_accepted() {
        for k in Kernel::registry() {
            let toml = format!("[coordinator]\nkernel = \"{}\"", k.name());
            let cfg = ServeConfig::from_toml(&Toml::parse(&toml).unwrap()).unwrap();
            assert_eq!(cfg.kernel.name(), k.name());
        }
        // the fused tier takes its tile width from [coordinator] tile_imgs
        let cfg = ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nkernel = \"fused\"\ntile_imgs = 5").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.kernel, Kernel::Fused { tile_imgs: 5 });
        // the pipelined tier takes its ring depth from [coordinator] ring_cap
        let cfg = ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nkernel = \"pipelined\"\nring_cap = 3").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.kernel, Kernel::Pipelined { ring_cap: 3 });
        // ...and defaults to DEFAULT_RING_CAP when the knob is absent
        let cfg = ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nkernel = \"pipelined\"").unwrap(),
        )
        .unwrap();
        assert_eq!(
            cfg.kernel,
            Kernel::Pipelined { ring_cap: crate::bnn::DEFAULT_RING_CAP }
        );
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nbackends = \"gpu\"").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[fpga]\nparallelism = 512").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[fpga]\nmem_style = \"dram\"").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nblock_rows = 0").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\ntile_imgs = 0").unwrap()
        )
        .is_err());
        // negative values must be rejected, not wrapped through `as usize`
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\ntile_imgs = -1").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nblock_rows = -8").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nring_cap = 0").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nring_cap = -2").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nworkers = -2").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nworkers = 0").unwrap()
        )
        .is_err());
        // an unknown kernel name fails at load time, not at first request
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nkernel = \"warp\"").unwrap()
        )
        .is_err());
        // degenerate queue caps fail at load time too
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nqueue_cap = 0").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[coordinator]\nqueue_cap = -5").unwrap()
        )
        .is_err());
        // connection-policy knobs validate on the signed value too
        assert!(ServeConfig::from_toml(
            &Toml::parse("[server]\nmax_conns = 0").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[server]\nmax_conns = -1").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[server]\nidle_timeout_ms = 0").unwrap()
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            &Toml::parse("[server]\nasync = 1").unwrap()
        )
        .is_err());
    }

    #[test]
    fn parses_model_sections() {
        let toml = r#"
[models.mnist-a]
weights = "artifacts/mnist_a/weights.json"
quota = 128
default = true

[models.mnist-b]
"#;
        let cfg = ServeConfig::from_toml(&Toml::parse(toml).unwrap()).unwrap();
        assert_eq!(cfg.models.len(), 2);
        // BTreeMap section order: sorted by name
        assert_eq!(
            cfg.models[0],
            ModelConfig {
                name: "mnist-a".into(),
                weights: Some("artifacts/mnist_a/weights.json".into()),
                quota: Some(128),
                default: true,
            }
        );
        assert_eq!(
            cfg.models[1],
            ModelConfig { name: "mnist-b".into(), weights: None, quota: None, default: false }
        );
        // no [models.*] sections → the classic single-model path
        let cfg = ServeConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert!(cfg.models.is_empty());
    }

    #[test]
    fn rejects_bad_model_sections() {
        // two defaults is ambiguous routing
        assert!(ServeConfig::from_toml(
            &Toml::parse("[models.a]\ndefault = true\n[models.b]\ndefault = true").unwrap()
        )
        .is_err());
        // negative quota must not wrap through `as usize`
        assert!(ServeConfig::from_toml(&Toml::parse("[models.a]\nquota = -1").unwrap()).is_err());
        // names must fit the wire's FEAT_MODEL length bound
        let long = format!("[models.{}]", "x".repeat(65));
        assert!(ServeConfig::from_toml(&Toml::parse(&long).unwrap()).is_err());
    }

    #[test]
    fn toml_value_types() {
        let t = Toml::parse("a = 1\nb = 1.5\nc = \"x\"\nd = true").unwrap();
        assert_eq!(t.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(t.get("", "b"), Some(&Value::Float(1.5)));
        assert_eq!(t.get("", "c"), Some(&Value::Str("x".into())));
        assert_eq!(t.get("", "d"), Some(&Value::Bool(true)));
        assert!(Toml::parse("nonsense").is_err());
        assert!(Toml::parse("k = @").is_err());
    }

    #[test]
    fn comments_and_sections() {
        let t = Toml::parse("# top\n[s1]\nx = 2 # inline\n[s2]\nx = 3").unwrap();
        assert_eq!(t.get("s1", "x"), Some(&Value::Int(2)));
        assert_eq!(t.get("s2", "x"), Some(&Value::Int(3)));
        assert_eq!(t.get("s3", "x"), None);
    }
}
