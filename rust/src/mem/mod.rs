//! Interchange formats: the paper's `.mem` hex files, the MNIST idx
//! container, and the `weights.json` model payload emitted by the Python
//! build path.

pub mod idx;
pub mod memfile;
pub mod weights;

pub use idx::{read_idx_images, read_idx_labels};
pub use memfile::{read_image_mem, read_label_mem, read_threshold_mem, read_weight_mem};
pub use weights::load_model;
