//! MNIST idx container codec (ubyte variants) — reads the dataset files the
//! Python build path writes (real MNIST files work identically if supplied).

use std::path::Path;

use anyhow::{bail, Context, Result};

fn read_u32_be(b: &[u8], at: usize) -> u32 {
    u32::from_be_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Read an idx3-ubyte image file: returns `(images, rows, cols)` with
/// `images[i]` a `rows*cols` byte vector.
pub fn read_idx_images(path: &Path) -> Result<(Vec<Vec<u8>>, usize, usize)> {
    let b = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if b.len() < 16 {
        bail!("idx3 file too short");
    }
    let magic = read_u32_be(&b, 0);
    if magic != 0x803 {
        bail!("bad idx3 magic {magic:#x}");
    }
    let n = read_u32_be(&b, 4) as usize;
    let rows = read_u32_be(&b, 8) as usize;
    let cols = read_u32_be(&b, 12) as usize;
    let expect = 16 + n * rows * cols;
    if b.len() != expect {
        bail!("idx3 length {} != expected {expect}", b.len());
    }
    let stride = rows * cols;
    let images = (0..n)
        .map(|i| b[16 + i * stride..16 + (i + 1) * stride].to_vec())
        .collect();
    Ok((images, rows, cols))
}

/// Read an idx1-ubyte label file.
pub fn read_idx_labels(path: &Path) -> Result<Vec<u8>> {
    let b = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if b.len() < 8 {
        bail!("idx1 file too short");
    }
    let magic = read_u32_be(&b, 0);
    if magic != 0x801 {
        bail!("bad idx1 magic {magic:#x}");
    }
    let n = read_u32_be(&b, 4) as usize;
    if b.len() != 8 + n {
        bail!("idx1 length {} != expected {}", b.len(), 8 + n);
    }
    Ok(b[8..].to_vec())
}

/// Write helpers (round-trip tests + Rust-side dataset generation).
pub fn write_idx_images(path: &Path, images: &[Vec<u8>], rows: usize, cols: usize) -> Result<()> {
    let mut out = Vec::with_capacity(16 + images.len() * rows * cols);
    out.extend_from_slice(&0x803u32.to_be_bytes());
    out.extend_from_slice(&(images.len() as u32).to_be_bytes());
    out.extend_from_slice(&(rows as u32).to_be_bytes());
    out.extend_from_slice(&(cols as u32).to_be_bytes());
    for img in images {
        if img.len() != rows * cols {
            bail!("image size {} != {}", img.len(), rows * cols);
        }
        out.extend_from_slice(img);
    }
    std::fs::write(path, out)?;
    Ok(())
}

pub fn write_idx_labels(path: &Path, labels: &[u8]) -> Result<()> {
    let mut out = Vec::with_capacity(8 + labels.len());
    out.extend_from_slice(&0x801u32.to_be_bytes());
    out.extend_from_slice(&(labels.len() as u32).to_be_bytes());
    out.extend_from_slice(labels);
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_idx");
        std::fs::create_dir_all(&dir).unwrap();
        let imgs: Vec<Vec<u8>> = (0..5).map(|i| vec![i as u8; 28 * 28]).collect();
        let labels = vec![0u8, 1, 2, 3, 4];
        write_idx_images(&dir.join("i"), &imgs, 28, 28).unwrap();
        write_idx_labels(&dir.join("l"), &labels).unwrap();
        let (got, r, c) = read_idx_images(&dir.join("i")).unwrap();
        assert_eq!((r, c), (28, 28));
        assert_eq!(got, imgs);
        assert_eq!(read_idx_labels(&dir.join("l")).unwrap(), labels);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_idx2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        let mut b = vec![0u8; 16];
        b[3] = 0x99;
        std::fs::write(&p, &b).unwrap();
        assert!(read_idx_images(&p).is_err());
        assert!(read_idx_labels(&p).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_idx3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc");
        let mut b = Vec::new();
        b.extend_from_slice(&0x803u32.to_be_bytes());
        b.extend_from_slice(&10u32.to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        b.extend_from_slice(&[0; 100]); // far less than 10*784
        std::fs::write(&p, &b).unwrap();
        assert!(read_idx_images(&p).is_err());
    }
}
