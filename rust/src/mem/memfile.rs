//! `.mem` hex-file codec — the paper's `$readmemh` interchange (§3.2).
//!
//! Layout (mirrors `python/compile/export.py`):
//! * weight/image files: one row per line, the row's bits as one MSB-first
//!   hex string (bit n−1 leftmost) — one neuron's full input weights, or
//!   one 784-bit binarized image;
//! * threshold files: one two's-complement 11-bit value per line (3 hex
//!   digits);
//! * label files: one hex digit per line.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bnn::packing::{pack_bits_u64, words_u64};

/// Parse one MSB-first hex row into LSB-first bits of length `n_bits`.
pub fn hex_row_to_bits(row: &str, n_bits: usize) -> Result<Vec<u8>> {
    let row = row.trim();
    let expected_digits = n_bits.div_ceil(4);
    if row.len() != expected_digits {
        bail!(
            "hex row has {} digits, expected {} for {} bits",
            row.len(),
            expected_digits,
            n_bits
        );
    }
    let mut bits = vec![0u8; n_bits];
    for (pos, ch) in row.chars().enumerate() {
        let v = ch.to_digit(16).with_context(|| format!("bad hex digit '{ch}'"))? as u8;
        // hex digit at string position `pos` covers logical bits
        // [4*(expected_digits-1-pos), +4)
        let base = 4 * (expected_digits - 1 - pos);
        for k in 0..4 {
            let bit_idx = base + k;
            if bit_idx < n_bits {
                bits[bit_idx] = (v >> k) & 1;
            } else if (v >> k) & 1 != 0 {
                bail!("padding bit {bit_idx} set in hex row");
            }
        }
    }
    Ok(bits)
}

/// Render LSB-first bits as one MSB-first hex row (inverse of the above).
pub fn bits_to_hex_row(bits: &[u8]) -> String {
    let digits = bits.len().div_ceil(4);
    let mut out = String::with_capacity(digits);
    for pos in 0..digits {
        let base = 4 * (digits - 1 - pos);
        let mut v = 0u8;
        for k in 0..4 {
            if base + k < bits.len() {
                v |= bits[base + k] << k;
            }
        }
        out.push(char::from_digit(v as u32, 16).unwrap());
    }
    out
}

fn read_lines(path: &Path) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading mem file {}", path.display()))?;
    Ok(text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with("//"))
        .map(str::to_string)
        .collect())
}

/// Read a weight `.mem`: `n_rows` rows of `n_bits` each, packed to u64 words
/// (row-major).  Returns `(words, words_per_row)`.
pub fn read_weight_mem(path: &Path, n_rows: usize, n_bits: usize) -> Result<(Vec<u64>, usize)> {
    let lines = read_lines(path)?;
    if lines.len() != n_rows {
        bail!("{} rows in {}, expected {n_rows}", lines.len(), path.display());
    }
    let wpr = words_u64(n_bits);
    let mut words = Vec::with_capacity(n_rows * wpr);
    for line in &lines {
        words.extend(pack_bits_u64(&hex_row_to_bits(line, n_bits)?));
    }
    Ok((words, wpr))
}

/// Read a threshold `.mem` (two's-complement values of `bits` width).
pub fn read_threshold_mem(path: &Path, bits: u32) -> Result<Vec<i32>> {
    let lines = read_lines(path)?;
    let sign = 1i64 << (bits - 1);
    let modulus = 1i64 << bits;
    lines
        .iter()
        .map(|l| {
            let v = i64::from_str_radix(l, 16).with_context(|| format!("bad threshold '{l}'"))?;
            if v >= modulus {
                bail!("threshold {l} out of {bits}-bit range");
            }
            Ok(if v >= sign { (v - modulus) as i32 } else { v as i32 })
        })
        .collect()
}

/// Read an image `.mem`: rows of `n_bits` binarized pixels, packed per image.
pub fn read_image_mem(path: &Path, n_bits: usize) -> Result<Vec<Vec<u64>>> {
    read_lines(path)?
        .iter()
        .map(|l| Ok(pack_bits_u64(&hex_row_to_bits(l, n_bits)?)))
        .collect()
}

/// Read a label `.mem`: one hex digit per line.
pub fn read_label_mem(path: &Path) -> Result<Vec<u8>> {
    read_lines(path)?
        .iter()
        .map(|l| {
            let v = u8::from_str_radix(l, 16).with_context(|| format!("bad label '{l}'"))?;
            if v > 9 {
                bail!("label {v} out of digit range");
            }
            Ok(v)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{gens, Runner};

    #[test]
    fn hex_row_roundtrip_property() {
        Runner::new("hex-row-roundtrip").run(&gens::BitVec(1..=800), |bits| {
            let row = bits_to_hex_row(bits);
            hex_row_to_bits(&row, bits.len()).map(|b| b == *bits).unwrap_or(false)
        });
    }

    #[test]
    fn hex_row_known_values() {
        // bits LSB-first [1,0,0,0] = value 1 → hex "1"
        assert_eq!(bits_to_hex_row(&[1, 0, 0, 0]), "1");
        // bits [0,0,0,1] = value 8 → hex "8"
        assert_eq!(bits_to_hex_row(&[0, 0, 0, 1]), "8");
        // 8 bits, MSB-first rendering: bit7=1 → "80"
        assert_eq!(bits_to_hex_row(&[0, 0, 0, 0, 0, 0, 0, 1]), "80");
        assert_eq!(hex_row_to_bits("80", 8).unwrap(), vec![0, 0, 0, 0, 0, 0, 0, 1]);
    }

    #[test]
    fn wrong_length_rejected() {
        assert!(hex_row_to_bits("ff", 784).is_err());
        // 5 bits → 2 hex digits; value with padding bits set must fail
        assert!(hex_row_to_bits("ff", 5).is_err());
        assert!(hex_row_to_bits("1f", 5).is_ok());
    }

    #[test]
    fn threshold_twos_complement() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_thr");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mem");
        // 11-bit: 0x7ff = -1, 0x400 = -1024, 0x3ff = 1023, 0x000 = 0
        std::fs::write(&p, "7ff\n400\n3ff\n000\n").unwrap();
        assert_eq!(read_threshold_mem(&p, 11).unwrap(), vec![-1, -1024, 1023, 0]);
        std::fs::write(&p, "800\n").unwrap(); // 12-bit value in an 11-bit file
        assert!(read_threshold_mem(&p, 11).is_err());
    }

    #[test]
    fn label_range_checked() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_lbl");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("l.mem");
        std::fs::write(&p, "0\n9\n").unwrap();
        assert_eq!(read_label_mem(&p).unwrap(), vec![0, 9]);
        std::fs::write(&p, "a\n").unwrap();
        assert!(read_label_mem(&p).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_cm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.mem");
        std::fs::write(&p, "// header\n\n0\n1\n").unwrap();
        assert_eq!(read_label_mem(&p).unwrap(), vec![0, 1]);
    }
}
