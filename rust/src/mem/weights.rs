//! `weights.json` loader — the trained/folded model payload emitted by
//! `python/compile/export.py`, plus a loader for the paper-format `.mem`
//! directory (both must produce identical models; tested in integration).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::memfile;
use crate::bnn::{BinaryDenseLayer, BnnModel};
use crate::util::json::Json;

/// Load a [`BnnModel`] from `artifacts/weights.json`.
pub fn load_model(path: &Path) -> Result<BnnModel> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading weights file {}", path.display()))?;
    let root = Json::parse(&text).context("parsing weights.json")?;
    let layers_json = root.get("layers")?.as_arr()?;
    if layers_json.is_empty() {
        bail!("weights.json has no layers");
    }
    let mut layers = Vec::with_capacity(layers_json.len());
    for (li, lj) in layers_json.iter().enumerate() {
        let n_in = lj.get("n_in")?.as_usize()?;
        let n_out = lj.get("n_out")?.as_usize()?;
        let rows_json = lj.get("w_packed")?.as_arr()?;
        if rows_json.len() != n_out {
            bail!("layer {li}: {} rows != n_out {n_out}", rows_json.len());
        }
        let mut rows = Vec::with_capacity(n_out);
        for rj in rows_json {
            let row: Result<Vec<u32>> =
                rj.as_arr()?.iter().map(|v| Ok(v.as_u64()? as u32)).collect();
            rows.push(row?);
        }
        let thresholds = match lj.opt("thresholds") {
            Some(tj) => Some(
                tj.as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_i64()? as i32))
                    .collect::<Result<Vec<i32>>>()?,
            ),
            None => None,
        };
        layers.push(BinaryDenseLayer::from_u32_rows(n_in, &rows, thresholds)?);
    }
    let model = BnnModel { layers };
    model.validate()?;
    Ok(model)
}

/// Load the same model from the paper-format `.mem` directory
/// (`weights_l{1..3}.mem` + `thresholds_l{1,2}.mem`) given the architecture.
pub fn load_model_from_mem(dir: &Path, dims: &[usize]) -> Result<BnnModel> {
    if dims.len() < 2 {
        bail!("need at least one layer");
    }
    let mut layers = Vec::new();
    for (i, w) in dims.windows(2).enumerate() {
        let (n_in, n_out) = (w[0], w[1]);
        let (words, wpr) =
            memfile::read_weight_mem(&dir.join(format!("weights_l{}.mem", i + 1)), n_out, n_in)?;
        let thresholds = if i + 2 < dims.len() {
            let t = memfile::read_threshold_mem(&dir.join(format!("thresholds_l{}.mem", i + 1)), 11)?;
            if t.len() != n_out {
                bail!("layer {i}: {} thresholds != {n_out} neurons", t.len());
            }
            Some(t)
        } else {
            None
        };
        layers.push(BinaryDenseLayer {
            n_in,
            n_out,
            weights: words,
            words_per_row: wpr,
            thresholds,
        });
    }
    let model = BnnModel { layers };
    model.validate()?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights_json() -> String {
        // 3-in → 2 hidden (thresholds) → 1 out
        r#"{
          "dims": [3, 2, 1],
          "layers": [
            {"n_in": 3, "n_out": 2, "w_packed": [[7],[0]], "thresholds": [1, -1]},
            {"n_in": 2, "n_out": 1, "w_packed": [[3]], "thresholds": null}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn loads_tiny_model() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_wjson");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weights.json");
        std::fs::write(&p, tiny_weights_json()).unwrap();
        let model = load_model(&p).unwrap();
        assert_eq!(model.layers.len(), 2);
        assert_eq!(model.n_in(), 3);
        assert_eq!(model.n_classes(), 1);
        // neuron 0 weights all +1 (packed 7 = 0b111); input all +1 → z = 3
        let x = crate::bnn::packing::pack_bits_u64(&[1, 1, 1]);
        // hidden: n0: z=3 ≥ 1 → 1; n1: weights 0b00 → all −1, z=−3 ≥ −1? no → 0
        // out: w=0b11 (+1,+1), a=(+1,−1) → z = 0
        assert_eq!(model.logits(&x), vec![0]);
    }

    #[test]
    fn row_count_mismatch_rejected() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_wjson2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weights.json");
        std::fs::write(
            &p,
            r#"{"layers": [{"n_in": 3, "n_out": 2, "w_packed": [[7]], "thresholds": [0,0]}]}"#,
        )
        .unwrap();
        assert!(load_model(&p).is_err());
    }

    #[test]
    fn mem_dir_roundtrip_matches_json() {
        use crate::mem::memfile::bits_to_hex_row;
        let dir = std::env::temp_dir().join("bnn_fpga_test_memdir");
        std::fs::create_dir_all(&dir).unwrap();
        // same tiny model in .mem format
        std::fs::write(
            dir.join("weights_l1.mem"),
            format!("{}\n{}\n", bits_to_hex_row(&[1, 1, 1]), bits_to_hex_row(&[0, 0, 0])),
        )
        .unwrap();
        std::fs::write(dir.join("thresholds_l1.mem"), "001\n7ff\n").unwrap(); // 1, -1
        std::fs::write(dir.join("weights_l2.mem"), format!("{}\n", bits_to_hex_row(&[1, 1])))
            .unwrap();
        let m = load_model_from_mem(&dir, &[3, 2, 1]).unwrap();

        let jp = dir.join("weights.json");
        std::fs::write(&jp, tiny_weights_json()).unwrap();
        let mj = load_model(&jp).unwrap();
        for (a, b) in m.layers.iter().zip(mj.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.thresholds, b.thresholds);
        }
    }
}
