//! `weights.json` loader — the trained/folded model payload emitted by
//! `python/compile/export.py`, plus a loader for the paper-format `.mem`
//! directory (both must produce identical models; tested in integration).
//!
//! Two format versions coexist:
//!
//! * **v1** (no `format_version`, no per-layer `type`): a dense-only
//!   stack — every layer is `{n_in, n_out, w_packed, thresholds}`.
//!   Pre-conv files keep loading byte-identically; an absent `type`
//!   defaults to `dense`.
//! * **v2** (`format_version: 2`): each layer carries a `type` tag from
//!   the [`LayerKind`] vocabulary.  `dense` layers are unchanged; `conv`
//!   layers add the spatial geometry
//!   (`in_ch/in_h/in_w/out_ch/kernel/stride/pad`) around a packed core of
//!   `out_ch` rows × `k²·in_ch` bits with mandatory thresholds.  Conv
//!   layers must form a prefix (the model is a conv→dense stack).
//!
//! Malformed v2 files fail with a **typed** [`FormatError`] — unknown
//! layer `type` or a missing per-kind field — citing both the layer index
//! and the line in the source text where that layer's object starts, so
//! a hand-edited weights file points straight at the offending entry.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::memfile;
use crate::bnn::conv::{BinaryConvLayer, LayerKind};
use crate::bnn::{BinaryDenseLayer, BnnModel};
use crate::util::json::{obj, Json};

/// Typed model-format error: what went wrong, in which layer, and the
/// 1-based line of that layer's object in the source text.  Carried
/// through `anyhow` so callers can `downcast_ref::<FormatError>()` while
/// CLI users still get the rendered message.
#[derive(Debug, PartialEq, Eq)]
pub enum FormatError {
    /// A `type` tag outside the [`LayerKind`] vocabulary.
    UnknownLayerType {
        layer: usize,
        line: usize,
        found: String,
    },
    /// A field the layer's `type` requires is absent (or JSON `null`).
    MissingField {
        layer: usize,
        line: usize,
        kind: LayerKind,
        field: &'static str,
    },
}

impl std::fmt::Display for FormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FormatError::UnknownLayerType { layer, line, found } => write!(
                f,
                "layer {layer} (line {line}): unknown layer type {found:?} \
                 (expected \"conv\" or \"dense\")"
            ),
            FormatError::MissingField {
                layer,
                line,
                kind,
                field,
            } => write!(
                f,
                "layer {layer} (line {line}): {} layer is missing required field {field:?}",
                kind.name()
            ),
        }
    }
}

impl std::error::Error for FormatError {}

/// 1-based line of the `li`-th object in the top-level `layers` array — a
/// text-level scan (string-aware brace walk) so [`FormatError`] can cite
/// the offending line without a position-tracking JSON parser.
fn layer_line(text: &str, li: usize) -> usize {
    let Some(start) = text.find("\"layers\"") else {
        return 1;
    };
    let mut line = 1 + text.as_bytes()[..start].iter().filter(|&&b| b == b'\n').count();
    let (mut in_str, mut esc) = (false, false);
    let mut arr = 0usize; // [..] nesting from the layers array inwards
    let mut obj_depth = 0usize; // {..} nesting inside a layer object
    let mut idx = 0usize;
    for &b in &text.as_bytes()[start..] {
        if b == b'\n' {
            line += 1;
            continue;
        }
        if in_str {
            match b {
                _ if esc => esc = false,
                b'\\' => esc = true,
                b'"' => in_str = false,
                _ => {}
            }
            continue;
        }
        match b {
            b'"' => in_str = true,
            b'[' => arr += 1,
            b']' => {
                if arr <= 1 {
                    break; // end of the layers array
                }
                arr -= 1;
            }
            b'{' if arr == 1 && obj_depth == 0 => {
                if idx == li {
                    return line;
                }
                idx += 1;
                obj_depth = 1;
            }
            b'{' if arr >= 1 => obj_depth += 1,
            b'}' if arr >= 1 && obj_depth > 0 => obj_depth -= 1,
            _ => {}
        }
    }
    line
}

/// `lj.get(field)` with absence mapped to the typed
/// [`FormatError::MissingField`] (line-cited).
fn req<'a>(
    lj: &'a Json,
    text: &str,
    li: usize,
    kind: LayerKind,
    field: &'static str,
) -> Result<&'a Json> {
    lj.opt(field).ok_or_else(|| {
        FormatError::MissingField {
            layer: li,
            line: layer_line(text, li),
            kind,
            field,
        }
        .into()
    })
}

fn parse_u32_rows(rows_json: &[Json]) -> Result<Vec<Vec<u32>>> {
    rows_json
        .iter()
        .map(|rj| rj.as_arr()?.iter().map(|v| Ok(v.as_u64()? as u32)).collect())
        .collect()
}

fn parse_thresholds(tj: &Json) -> Result<Vec<i32>> {
    tj.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect()
}

/// Load a [`BnnModel`] from `artifacts/weights.json` (v1 or v2).
pub fn load_model(path: &Path) -> Result<BnnModel> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading weights file {}", path.display()))?;
    load_model_from_str(&text)
}

/// [`load_model`] on an in-memory JSON document (wire/test entry point).
pub fn load_model_from_str(text: &str) -> Result<BnnModel> {
    let root = Json::parse(text).context("parsing weights.json")?;
    let layers_json = root.get("layers")?.as_arr()?;
    if layers_json.is_empty() {
        bail!("weights.json has no layers");
    }
    let mut conv = Vec::new();
    let mut layers = Vec::new();
    for (li, lj) in layers_json.iter().enumerate() {
        let kind = match lj.opt("type") {
            None => LayerKind::Dense, // v1 files carry no tag
            Some(tag) => {
                let s = tag.as_str().with_context(|| format!("layer {li}: 'type' tag"))?;
                LayerKind::parse(s).ok_or_else(|| FormatError::UnknownLayerType {
                    layer: li,
                    line: layer_line(text, li),
                    found: s.to_string(),
                })?
            }
        };
        match kind {
            LayerKind::Dense => {
                let n_in = req(lj, text, li, kind, "n_in")?.as_usize()?;
                let n_out = req(lj, text, li, kind, "n_out")?.as_usize()?;
                let rows_json = req(lj, text, li, kind, "w_packed")?.as_arr()?;
                if rows_json.len() != n_out {
                    bail!("layer {li}: {} rows != n_out {n_out}", rows_json.len());
                }
                let rows = parse_u32_rows(rows_json)
                    .with_context(|| format!("layer {li}: w_packed"))?;
                let thresholds = lj.opt("thresholds").map(parse_thresholds).transpose()?;
                layers.push(BinaryDenseLayer::from_u32_rows(n_in, &rows, thresholds)?);
            }
            LayerKind::Conv => {
                if !layers.is_empty() {
                    bail!("layer {li}: conv layers must form a prefix (dense seen earlier)");
                }
                let in_ch = req(lj, text, li, kind, "in_ch")?.as_usize()?;
                let in_h = req(lj, text, li, kind, "in_h")?.as_usize()?;
                let in_w = req(lj, text, li, kind, "in_w")?.as_usize()?;
                let out_ch = req(lj, text, li, kind, "out_ch")?.as_usize()?;
                let kernel = req(lj, text, li, kind, "kernel")?.as_usize()?;
                let stride = req(lj, text, li, kind, "stride")?.as_usize()?;
                let pad = req(lj, text, li, kind, "pad")?.as_usize()?;
                let rows_json = req(lj, text, li, kind, "w_packed")?.as_arr()?;
                if rows_json.len() != out_ch {
                    bail!("layer {li}: {} rows != out_ch {out_ch}", rows_json.len());
                }
                let rows = parse_u32_rows(rows_json)
                    .with_context(|| format!("layer {li}: w_packed"))?;
                let thr = parse_thresholds(req(lj, text, li, kind, "thresholds")?)?;
                let core =
                    BinaryDenseLayer::from_u32_rows(kernel * kernel * in_ch, &rows, Some(thr))
                        .with_context(|| format!("layer {li}: conv core"))?;
                conv.push(
                    BinaryConvLayer::new(in_ch, in_h, in_w, kernel, stride, pad, core)
                        .with_context(|| format!("layer {li}: conv geometry"))?,
                );
            }
        }
    }
    let model = BnnModel::with_conv(conv, layers);
    model.validate()?;
    Ok(model)
}

/// Serialize a model as a format-v2 document (`type`-tagged layers).
/// Inverse of [`load_model_from_str`] — pinned by the round-trip tests
/// below and exercised end-to-end by `tests/conv_conformance.rs`.
pub fn model_to_json(model: &BnnModel) -> Json {
    let mut layers = Vec::new();
    for cl in &model.conv {
        layers.push(obj(vec![
            ("type", Json::Str(LayerKind::Conv.name().to_string())),
            ("in_ch", Json::Num(cl.in_ch as f64)),
            ("in_h", Json::Num(cl.in_h as f64)),
            ("in_w", Json::Num(cl.in_w as f64)),
            ("out_ch", Json::Num(cl.out_ch() as f64)),
            ("kernel", Json::Num(cl.kernel as f64)),
            ("stride", Json::Num(cl.stride as f64)),
            ("pad", Json::Num(cl.pad as f64)),
            ("w_packed", packed_rows_json(&cl.core)),
            (
                "thresholds",
                thresholds_json(cl.core.thresholds.as_deref().unwrap_or(&[])),
            ),
        ]));
    }
    for dl in &model.layers {
        let mut fields = vec![
            ("type", Json::Str(LayerKind::Dense.name().to_string())),
            ("n_in", Json::Num(dl.n_in as f64)),
            ("n_out", Json::Num(dl.n_out as f64)),
            ("w_packed", packed_rows_json(dl)),
        ];
        if let Some(thr) = &dl.thresholds {
            fields.push(("thresholds", thresholds_json(thr)));
        }
        layers.push(obj(fields));
    }
    obj(vec![
        ("format_version", Json::Num(2.0)),
        ("layers", Json::Arr(layers)),
    ])
}

/// Write a model as format v2 (see [`model_to_json`]).
pub fn save_model(path: &Path, model: &BnnModel) -> Result<()> {
    std::fs::write(path, model_to_json(model).to_string())
        .with_context(|| format!("writing weights file {}", path.display()))
}

fn packed_rows_json(layer: &BinaryDenseLayer) -> Json {
    let rows = (0..layer.n_out)
        .map(|j| {
            let words = crate::bnn::packing::u64_words_to_u32(layer.row(j), layer.n_in);
            Json::Arr(words.iter().map(|&w| Json::Num(w as f64)).collect())
        })
        .collect();
    Json::Arr(rows)
}

fn thresholds_json(thr: &[i32]) -> Json {
    Json::Arr(thr.iter().map(|&t| Json::Num(t as f64)).collect())
}

/// Load the same model from the paper-format `.mem` directory
/// (`weights_l{1..3}.mem` + `thresholds_l{1,2}.mem`) given the architecture.
pub fn load_model_from_mem(dir: &Path, dims: &[usize]) -> Result<BnnModel> {
    if dims.len() < 2 {
        bail!("need at least one layer");
    }
    let mut layers = Vec::new();
    for (i, w) in dims.windows(2).enumerate() {
        let (n_in, n_out) = (w[0], w[1]);
        let (words, wpr) =
            memfile::read_weight_mem(&dir.join(format!("weights_l{}.mem", i + 1)), n_out, n_in)?;
        let thresholds = if i + 2 < dims.len() {
            let t = memfile::read_threshold_mem(&dir.join(format!("thresholds_l{}.mem", i + 1)), 11)?;
            if t.len() != n_out {
                bail!("layer {i}: {} thresholds != {n_out} neurons", t.len());
            }
            Some(t)
        } else {
            None
        };
        layers.push(BinaryDenseLayer {
            n_in,
            n_out,
            weights: words,
            words_per_row: wpr,
            thresholds,
        });
    }
    let model = BnnModel::dense(layers);
    model.validate()?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::conv::random_conv_model;

    fn tiny_weights_json() -> String {
        // 3-in → 2 hidden (thresholds) → 1 out
        r#"{
          "dims": [3, 2, 1],
          "layers": [
            {"n_in": 3, "n_out": 2, "w_packed": [[7],[0]], "thresholds": [1, -1]},
            {"n_in": 2, "n_out": 1, "w_packed": [[3]], "thresholds": null}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn loads_tiny_model() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_wjson");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weights.json");
        std::fs::write(&p, tiny_weights_json()).unwrap();
        let model = load_model(&p).unwrap();
        assert_eq!(model.layers.len(), 2);
        assert_eq!(model.n_in(), 3);
        assert_eq!(model.n_classes(), 1);
        // neuron 0 weights all +1 (packed 7 = 0b111); input all +1 → z = 3
        let x = crate::bnn::packing::pack_bits_u64(&[1, 1, 1]);
        // hidden: n0: z=3 ≥ 1 → 1; n1: weights 0b00 → all −1, z=−3 ≥ −1? no → 0
        // out: w=0b11 (+1,+1), a=(+1,−1) → z = 0
        assert_eq!(model.logits(&x), vec![0]);
    }

    #[test]
    fn row_count_mismatch_rejected() {
        let dir = std::env::temp_dir().join("bnn_fpga_test_wjson2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("weights.json");
        std::fs::write(
            &p,
            r#"{"layers": [{"n_in": 3, "n_out": 2, "w_packed": [[7]], "thresholds": [0,0]}]}"#,
        )
        .unwrap();
        assert!(load_model(&p).is_err());
    }

    #[test]
    fn v1_files_without_type_default_to_dense() {
        // satellite pin: the pre-conv schema (no format_version, no
        // per-layer type) must keep loading unchanged
        let model = load_model_from_str(&tiny_weights_json()).unwrap();
        assert!(model.conv.is_empty());
        assert_eq!(model.layers.len(), 2);
        // an explicit v2 tag on the same payload loads identically
        let tagged = r#"{
          "format_version": 2,
          "layers": [
            {"type": "dense", "n_in": 3, "n_out": 2, "w_packed": [[7],[0]],
             "thresholds": [1, -1]},
            {"type": "dense", "n_in": 2, "n_out": 1, "w_packed": [[3]]}
          ]
        }"#;
        let m2 = load_model_from_str(tagged).unwrap();
        for (a, b) in model.layers.iter().zip(m2.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.thresholds, b.thresholds);
        }
    }

    #[test]
    fn unknown_layer_type_is_a_typed_line_cited_error() {
        let text = "{\n \"layers\": [\n  {\"type\": \"pool\", \"n_in\": 3}\n ]\n}";
        let err = load_model_from_str(text).unwrap_err();
        let fe = err
            .downcast_ref::<FormatError>()
            .expect("unknown type must surface as FormatError");
        assert_eq!(
            *fe,
            FormatError::UnknownLayerType {
                layer: 0,
                line: 3,
                found: "pool".to_string(),
            }
        );
        assert!(err.to_string().contains("line 3"), "{err}");
    }

    #[test]
    fn missing_conv_field_is_a_typed_line_cited_error() {
        // a conv layer with no "kernel" — and sitting after another layer
        // so the line scan must skip layer 0's nested arrays/braces
        let text = concat!(
            "{\n",
            " \"format_version\": 2,\n",
            " \"layers\": [\n",
            "  {\"type\": \"conv\", \"in_ch\": 1, \"in_h\": 4, \"in_w\": 4,\n",
            "   \"out_ch\": 2, \"kernel\": 3, \"stride\": 1, \"pad\": 0,\n",
            "   \"w_packed\": [[0], [1]], \"thresholds\": [0, 0]},\n",
            "  {\"type\": \"conv\", \"in_ch\": 2, \"in_h\": 2, \"in_w\": 2,\n",
            "   \"out_ch\": 1, \"stride\": 1, \"pad\": 0,\n",
            "   \"w_packed\": [[0]], \"thresholds\": [0]}\n",
            " ]\n",
            "}"
        );
        let err = load_model_from_str(text).unwrap_err();
        let fe = err
            .downcast_ref::<FormatError>()
            .expect("missing field must surface as FormatError");
        assert_eq!(
            *fe,
            FormatError::MissingField {
                layer: 1,
                line: 7,
                kind: LayerKind::Conv,
                field: "kernel",
            }
        );
        // a dense layer missing w_packed is typed too
        let text = "{\"layers\": [{\"type\": \"dense\", \"n_in\": 3, \"n_out\": 1}]}";
        let err = load_model_from_str(text).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<FormatError>(),
            Some(FormatError::MissingField {
                layer: 0,
                kind: LayerKind::Dense,
                field: "w_packed",
                ..
            })
        ));
    }

    #[test]
    fn conv_model_round_trips_through_format_v2() {
        let model = random_conv_model((1, 8, 8), &[(5, 3, 1, 1)], &[16, 10], 77);
        let text = model_to_json(&model).to_string();
        let back = load_model_from_str(&text).unwrap();
        assert_eq!(back.conv.len(), 1);
        assert_eq!(back.conv[0].core.weights, model.conv[0].core.weights);
        assert_eq!(back.conv[0].core.thresholds, model.conv[0].core.thresholds);
        assert_eq!(back.conv[0].kernel, 3);
        for (a, b) in model.layers.iter().zip(back.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.thresholds, b.thresholds);
        }
        // and the reloaded model computes identical logits
        let bits: Vec<u8> = (0..model.n_in()).map(|i| (i % 3 == 0) as u8).collect();
        let x = crate::bnn::packing::pack_bits_u64(&bits);
        assert_eq!(back.logits(&x), model.logits(&x));
        // conv-after-dense is rejected (the model is a conv→dense stack)
        let bad = r#"{"layers": [
          {"type": "dense", "n_in": 4, "n_out": 1, "w_packed": [[0]]},
          {"type": "conv", "in_ch": 1, "in_h": 2, "in_w": 2, "out_ch": 1,
           "kernel": 1, "stride": 1, "pad": 0, "w_packed": [[1]], "thresholds": [0]}
        ]}"#;
        assert!(load_model_from_str(bad).unwrap_err().to_string().contains("prefix"));
    }

    #[test]
    fn mem_dir_roundtrip_matches_json() {
        use crate::mem::memfile::bits_to_hex_row;
        let dir = std::env::temp_dir().join("bnn_fpga_test_memdir");
        std::fs::create_dir_all(&dir).unwrap();
        // same tiny model in .mem format
        std::fs::write(
            dir.join("weights_l1.mem"),
            format!("{}\n{}\n", bits_to_hex_row(&[1, 1, 1]), bits_to_hex_row(&[0, 0, 0])),
        )
        .unwrap();
        std::fs::write(dir.join("thresholds_l1.mem"), "001\n7ff\n").unwrap(); // 1, -1
        std::fs::write(dir.join("weights_l2.mem"), format!("{}\n", bits_to_hex_row(&[1, 1])))
            .unwrap();
        let m = load_model_from_mem(&dir, &[3, 2, 1]).unwrap();

        let jp = dir.join("weights.json");
        std::fs::write(&jp, tiny_weights_json()).unwrap();
        let mj = load_model(&jp).unwrap();
        for (a, b) in m.layers.iter().zip(mj.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.thresholds, b.thresholds);
        }
    }
}
