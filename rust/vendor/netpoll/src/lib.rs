//! First-party offline subset of a readiness-polling library (mio-style).
//!
//! The offline build environment has no crates.io access (DESIGN.md
//! §Substitutions in the main crate), so instead of depending on `mio` this
//! vendored crate implements the small slice the async wire server needs:
//!
//! - register raw fds with a token and a read/write [`Interest`]
//! - block until one or more fds become ready, collecting [`Event`]s
//! - re-register (modify) interest as write buffers fill and drain
//!
//! Two backends sit behind one [`Poller`] facade:
//!
//! - **epoll** (Linux): level-triggered `epoll_(create1|ctl|wait)` via direct
//!   `extern "C"` bindings — std already links libc, so no external crate is
//!   needed. Level-triggered semantics keep the caller's state machine simple:
//!   an fd with unread bytes reports readable on every wait.
//! - **poll(2)** (portable fallback): a registration map snapshotted into a
//!   `pollfd` array per wait. O(n) per wait, fine for tests and non-Linux
//!   hosts, and selectable at runtime with `NETPOLL_FORCE_POLL=1` (mirroring
//!   the main crate's `BNN_FORCE_SCALAR` idiom) so CI can pin the fallback on
//!   Linux too.
//!
//! Both backends fold error/hangup conditions (`EPOLLERR`/`EPOLLHUP`,
//! `POLLERR`/`POLLHUP`/`POLLNVAL`) into *both* `readable` and `writable` so a
//! connection handler discovers the failure at its next read/write rather
//! than needing a third code path; `Event::hangup` is still set for callers
//! that want to fast-path teardown.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// What readiness a registration wants to be woken for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const READ: Interest = Interest { read: true, write: false };
    pub const WRITE: Interest = Interest { read: false, write: true };
    pub const BOTH: Interest = Interest { read: true, write: true };
}

/// One readiness notification: the registered token plus what fired.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up or the fd errored; `readable`/`writable` are also set.
    pub hangup: bool,
}

/// Reusable event buffer filled by [`Poller::wait`].
#[derive(Default)]
pub struct Events {
    inner: Vec<Event>,
}

impl Events {
    pub fn with_capacity(cap: usize) -> Self {
        Events { inner: Vec::with_capacity(cap) }
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Event> {
        self.inner.iter()
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    fn clear(&mut self) {
        self.inner.clear();
    }

    fn push(&mut self, ev: Event) {
        self.inner.push(ev);
    }
}

impl<'a> IntoIterator for &'a Events {
    type Item = &'a Event;
    type IntoIter = std::slice::Iter<'a, Event>;
    fn into_iter(self) -> Self::IntoIter {
        self.inner.iter()
    }
}

// ---------------------------------------------------------------------------
// epoll backend (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, Events, Interest};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    // Kernel ABI: packed on x86-64, natural alignment elsewhere.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Epoll {
        epfd: RawFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut mask = 0u32;
            if interest.read {
                mask |= EPOLLIN;
            }
            if interest.write {
                mask |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events: mask, data: token as u64 };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // A null event pointer is accepted on kernels >= 2.6.9; pass a
            // real (ignored) struct anyway for maximum compatibility.
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Events, timeout: Option<Duration>) -> io::Result<usize> {
            const CAP: usize = 256;
            let mut buf = [EpollEvent { events: 0, data: 0 }; CAP];
            let timeout_ms: i32 = match timeout {
                None => -1,
                Some(d) if d.is_zero() => 0,
                // Round sub-millisecond timeouts up so "wait a little" never
                // degenerates into a busy spin.
                Some(d) => i32::try_from(d.as_millis().max(1)).unwrap_or(i32::MAX),
            };
            let n = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), CAP as i32, timeout_ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(err);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct by value.
                let mask = ev.events;
                let token = ev.data as usize;
                let hup = mask & (EPOLLHUP | EPOLLERR) != 0;
                out.push(Event {
                    token,
                    readable: mask & EPOLLIN != 0 || hup,
                    writable: mask & EPOLLOUT != 0 || hup,
                    hangup: hup,
                });
            }
            Ok(n as usize)
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// poll(2) backend (portable fallback)
// ---------------------------------------------------------------------------

mod pollfall {
    use super::{Event, Events, Interest};
    use std::collections::BTreeMap;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::sync::Mutex;
    use std::time::Duration;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    #[cfg(target_os = "linux")]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout_ms: i32) -> i32;
    }

    /// Registration-map-based fallback: each `wait` snapshots the map into a
    /// `pollfd` array. The map lives behind a mutex so registration from the
    /// owning thread and waits interleave safely (the async server only ever
    /// drives a poller from one thread, but the API shouldn't require that).
    pub struct PollBackend {
        registry: Mutex<BTreeMap<RawFd, (usize, Interest)>>,
    }

    impl PollBackend {
        pub fn new() -> io::Result<Self> {
            Ok(PollBackend { registry: Mutex::new(BTreeMap::new()) })
        }

        pub fn register(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut reg = self.registry.lock().unwrap();
            if reg.contains_key(&fd) {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    format!("fd {fd} already registered"),
                ));
            }
            reg.insert(fd, (token, interest));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut reg = self.registry.lock().unwrap();
            match reg.get_mut(&fd) {
                Some(slot) => {
                    *slot = (token, interest);
                    Ok(())
                }
                None => Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("fd {fd} not registered"),
                )),
            }
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let mut reg = self.registry.lock().unwrap();
            match reg.remove(&fd) {
                Some(_) => Ok(()),
                None => Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("fd {fd} not registered"),
                )),
            }
        }

        pub fn wait(&self, out: &mut Events, timeout: Option<Duration>) -> io::Result<usize> {
            let mut fds: Vec<PollFd> = Vec::new();
            let mut tokens: Vec<usize> = Vec::new();
            {
                let reg = self.registry.lock().unwrap();
                for (&fd, &(token, interest)) in reg.iter() {
                    let mut mask = 0i16;
                    if interest.read {
                        mask |= POLLIN;
                    }
                    if interest.write {
                        mask |= POLLOUT;
                    }
                    fds.push(PollFd { fd, events: mask, revents: 0 });
                    tokens.push(token);
                }
            }
            let timeout_ms: i32 = match timeout {
                None => -1,
                Some(d) if d.is_zero() => 0,
                Some(d) => i32::try_from(d.as_millis().max(1)).unwrap_or(i32::MAX),
            };
            if fds.is_empty() {
                // poll(2) with nfds == 0 is a valid sleep, but spell it out.
                if timeout_ms > 0 {
                    std::thread::sleep(Duration::from_millis(timeout_ms as u64));
                }
                return Ok(0);
            }
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(err);
            }
            for (pfd, &token) in fds.iter().zip(tokens.iter()) {
                let re = pfd.revents;
                if re == 0 {
                    continue;
                }
                let hup = re & (POLLERR | POLLHUP | POLLNVAL) != 0;
                out.push(Event {
                    token,
                    readable: re & POLLIN != 0 || hup,
                    writable: re & POLLOUT != 0 || hup,
                    hangup: hup,
                });
            }
            Ok(n as usize)
        }
    }
}

// ---------------------------------------------------------------------------
// Facade
// ---------------------------------------------------------------------------

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    Poll(pollfall::PollBackend),
}

/// Readiness poller over raw fds. See the crate docs for backend selection.
pub struct Poller {
    backend: Backend,
}

fn force_poll() -> bool {
    matches!(std::env::var("NETPOLL_FORCE_POLL"), Ok(v) if v == "1")
}

impl Poller {
    /// Platform-preferred backend: epoll on Linux (unless
    /// `NETPOLL_FORCE_POLL=1`), poll(2) elsewhere.
    pub fn new() -> io::Result<Self> {
        #[cfg(target_os = "linux")]
        {
            if !force_poll() {
                return Ok(Poller { backend: Backend::Epoll(epoll::Epoll::new()?) });
            }
        }
        Self::new_poll()
    }

    /// Explicitly construct the portable poll(2) backend.
    pub fn new_poll() -> io::Result<Self> {
        Ok(Poller { backend: Backend::Poll(pollfall::PollBackend::new()?) })
    }

    /// Explicitly construct the epoll backend (Linux only).
    #[cfg(target_os = "linux")]
    pub fn new_epoll() -> io::Result<Self> {
        Ok(Poller { backend: Backend::Epoll(epoll::Epoll::new()?) })
    }

    /// Human-readable backend name (for server banners / reports).
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            Backend::Poll(_) => "poll",
        }
    }

    pub fn register(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.register(fd, token, interest),
            Backend::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn modify(&self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.modify(fd, token, interest),
            Backend::Poll(p) => p.modify(fd, token, interest),
        }
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.deregister(fd),
            Backend::Poll(p) => p.deregister(fd),
        }
    }

    /// Block until readiness or timeout; ready events are appended to `out`
    /// (which is cleared first). `None` blocks indefinitely. Returns the
    /// number of events delivered; `Ok(0)` on timeout or `EINTR`.
    pub fn wait(&self, out: &mut Events, timeout: Option<Duration>) -> io::Result<usize> {
        out.clear();
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.wait(out, timeout),
            Backend::Poll(p) => p.wait(out, timeout),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    fn wait_for(
        poller: &Poller,
        events: &mut Events,
        pred: impl Fn(&Event) -> bool,
        deadline: Duration,
    ) -> bool {
        let start = Instant::now();
        while start.elapsed() < deadline {
            poller.wait(events, Some(Duration::from_millis(50))).unwrap();
            if events.iter().any(&pred) {
                return true;
            }
        }
        false
    }

    fn pollers() -> Vec<Poller> {
        let mut v = vec![Poller::new_poll().unwrap()];
        #[cfg(target_os = "linux")]
        v.push(Poller::new_epoll().unwrap());
        v
    }

    #[test]
    fn readiness_round_trip_on_every_backend() {
        for poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            poller.register(listener.as_raw_fd(), 1, Interest::READ).unwrap();

            let mut events = Events::with_capacity(16);
            // Nothing pending: a short wait delivers no listener event.
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(
                events.iter().all(|e| e.token != 1),
                "{}: spurious listener readiness",
                poller.backend_name()
            );

            let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            assert!(
                wait_for(&poller, &mut events, |e| e.token == 1 && e.readable, Duration::from_secs(5)),
                "{}: listener never became readable",
                poller.backend_name()
            );

            let (mut server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();
            poller.register(server_side.as_raw_fd(), 2, Interest::READ).unwrap();

            let mut client = client;
            client.write_all(b"ping").unwrap();
            assert!(
                wait_for(&poller, &mut events, |e| e.token == 2 && e.readable, Duration::from_secs(5)),
                "{}: connection never became readable",
                poller.backend_name()
            );
            let mut buf = [0u8; 4];
            server_side.read_exact(&mut buf).unwrap();
            assert_eq!(&buf, b"ping");

            poller.deregister(server_side.as_raw_fd()).unwrap();
            poller.deregister(listener.as_raw_fd()).unwrap();
        }
    }

    #[test]
    fn modify_switches_interest_to_writable() {
        for poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();

            // Registered read-only: an idle connected socket reports nothing.
            poller.register(server_side.as_raw_fd(), 7, Interest::READ).unwrap();
            let mut events = Events::with_capacity(16);
            poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(
                events.iter().all(|e| e.token != 7),
                "{}: idle read-registered socket fired",
                poller.backend_name()
            );

            // Switch to write interest: an empty send buffer is instantly ready.
            poller.modify(server_side.as_raw_fd(), 7, Interest::WRITE).unwrap();
            assert!(
                wait_for(&poller, &mut events, |e| e.token == 7 && e.writable, Duration::from_secs(5)),
                "{}: writable readiness never delivered after modify",
                poller.backend_name()
            );

            poller.deregister(server_side.as_raw_fd()).unwrap();
            drop(client);
        }
    }

    #[test]
    fn deregister_stops_event_delivery() {
        for poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();

            poller.register(server_side.as_raw_fd(), 3, Interest::READ).unwrap();
            client.write_all(b"x").unwrap();
            let mut events = Events::with_capacity(16);
            assert!(
                wait_for(&poller, &mut events, |e| e.token == 3 && e.readable, Duration::from_secs(5)),
                "{}: readable never delivered",
                poller.backend_name()
            );

            poller.deregister(server_side.as_raw_fd()).unwrap();
            // The byte is still unread, but a deregistered fd must stay silent.
            poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            assert!(
                events.iter().all(|e| e.token != 3),
                "{}: deregistered fd still delivered events",
                poller.backend_name()
            );
        }
    }

    #[test]
    fn hangup_surfaces_as_readable() {
        for poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();
            poller.register(server_side.as_raw_fd(), 9, Interest::READ).unwrap();

            drop(client); // peer closes -> HUP (or plain EOF readability)
            let mut events = Events::with_capacity(16);
            assert!(
                wait_for(&poller, &mut events, |e| e.token == 9 && e.readable, Duration::from_secs(5)),
                "{}: peer close never surfaced",
                poller.backend_name()
            );
            poller.deregister(server_side.as_raw_fd()).unwrap();
        }
    }

    #[test]
    fn empty_registry_wait_times_out() {
        for poller in pollers() {
            let mut events = Events::with_capacity(4);
            let start = Instant::now();
            let n = poller.wait(&mut events, Some(Duration::from_millis(30))).unwrap();
            assert_eq!(n, 0, "{}", poller.backend_name());
            assert!(events.is_empty());
            assert!(
                start.elapsed() >= Duration::from_millis(20),
                "{}: empty wait returned early",
                poller.backend_name()
            );
        }
    }

    #[test]
    fn force_poll_env_selects_fallback() {
        // Don't mutate the env (tests run in parallel); check the predicate
        // logic and the constructor directly instead.
        let p = Poller::new_poll().unwrap();
        assert_eq!(p.backend_name(), "poll");
        #[cfg(target_os = "linux")]
        {
            let e = Poller::new_epoll().unwrap();
            assert_eq!(e.backend_name(), "epoll");
        }
    }
}
