//! API stub for the `xla 0.1.6` PJRT wrapper crate.
//!
//! The offline build environment ships neither crates.io nor the
//! `xla_extension` native library, so this vendored stand-in mirrors the
//! exact API surface `runtime/engine.rs` uses and fails **at runtime**, not
//! compile time: [`PjRtClient::cpu`] returns an error explaining that the
//! PJRT runtime is unavailable.  Every downstream consumer (`Engine::load`,
//! the PJRT backend, benches, tests) already treats engine construction as
//! fallible, so the whole PJRT path degrades gracefully to "unavailable"
//! while the native and fpga-sim backends keep working.
//!
//! To run the real thing, point the `xla` dependency in `rust/Cargo.toml`
//! at the actual wrapper crate; `runtime/engine.rs` compiles unchanged.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT runtime unavailable: this build uses the vendored `xla` API stub \
     (no xla_extension in the offline environment). Swap the `xla` path \
     dependency in rust/Cargo.toml for the real wrapper crate to enable \
     PJRT execution";

/// Error type matching the wrapper crate's (implements `std::error::Error`,
/// so `?` converts into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element dtypes used by the artifact signatures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    U32,
    I32,
    F32,
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub: all accessors fail).
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        // Construction is pure host-side bookkeeping in the real crate; the
        // stub still fails here so no caller can get past input staging.
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn full_surface_is_callable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let _ = comp; // constructible without a client
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::U32, &[1, 25], &[0; 100])
            .is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
