//! First-party subset of the `anyhow` error-handling crate.
//!
//! The offline build environment has no crates.io access (DESIGN.md
//! §Substitutions), so this vendored crate provides the slice of the
//! `anyhow 1.x` API the workspace actually uses, with the same semantics:
//!
//! * [`Error`] — a context-chain error type.  Like real `anyhow::Error` it
//!   deliberately does **not** implement `std::error::Error`, which is what
//!   makes the blanket `From<E: std::error::Error>` conversion coherent.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Formatting matches the common uses: `{e}` prints the outermost message,
//! `{e:#}` the full chain joined with `": "`, and `{e:?}` an
//! anyhow-style `Caused by:` listing (what `unwrap()`/`expect()` show).

use std::fmt;

/// Context-chain error: `chain[0]` is the outermost (most recent) message,
/// the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an additional layer of context (becomes the new outermost
    /// message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root-cause message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }

    /// Number of layers in the context chain (≥ 1).
    pub fn chain_len(&self) -> usize {
        self.chain.len()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, outermost first: "ctx: ctx: cause"
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion every `?` on a std error relies on.  Coherent only
// because `Error` itself does not implement `std::error::Error` (the same
// trade real anyhow makes).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    /// Wrap the error/none case with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with lazily-evaluated context (avoids the format cost on the
    /// success path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening config: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value for {}", "k")).unwrap_err();
        assert_eq!(format!("{e}"), "no value for k");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_construct_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
